//! FIt-SNE: FFT-accelerated interpolation-based repulsive forces
//! (Linderman, Rachh, Hoskins, Steinerberger, Kluger — Nature Methods 2019).
//!
//! The paper's Figures 4–5 and Table 4 compare Acc-t-SNE against FIt-SNE, so
//! the whole engine is built here: the repulsive N-body sums are evaluated by
//! scattering charges onto a regular grid (Lagrange interpolation, [`interp`]),
//! convolving with the squared-Cauchy kernels via FFT ([`fft`]), and gathering
//! back. Replaces the quadtree (steps 3/4/6) inside the [`crate::tsne`]
//! pipeline; KNN/BSP/attractive are shared.
//!
//! Charges and kernels (2-D embedding):
//! - `K1(d) = (1+d²)⁻¹`, charge 1 → Z (after subtracting N self-terms);
//! - `K2(d) = (1+d²)⁻²`, charges (1, x_j, y_j) →
//!   `raw_i = y_i·φ_1(i) − φ_{x,y}(i)` (the un-normalized repulsive force).

pub mod fft;
pub mod interp;

use crate::common::float::Real;
use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};
use fft::{fft2_inplace, Cpx};
use interp::{lagrange_weights, P_NODES};

/// FIt-SNE tuning knobs (Linderman defaults scaled to this testbed).
#[derive(Clone, Copy, Debug)]
pub struct FitsneParams {
    /// Minimum grid intervals per dimension.
    pub min_intervals: usize,
    /// Cap on intervals (bounds FFT memory: grid = intervals × P_NODES).
    pub max_intervals: usize,
    /// Target interval side length (kernel scale is 1 ⇒ ~1.0).
    pub interval_size: f64,
}

impl Default for FitsneParams {
    fn default() -> Self {
        FitsneParams {
            min_intervals: 50,
            max_intervals: 128,
            interval_size: 1.0,
        }
    }
}

/// Number of charge vectors batched through the K2 convolution.
const N_TERMS: usize = 3; // (1, x, y)

/// Compute FIt-SNE repulsive accumulations (same contract as the BH
/// kernels in [`crate::gradient::repulsive`]) into a caller-owned `raw`
/// buffer (`2n`, original order); returns the ordered-pair normalization Z.
/// The pipeline's hot loop reuses one buffer across iterations instead of
/// allocating `2n` floats per step (the allocating wrapper is gone with the
/// rest of the compatibility wrappers).
pub fn fitsne_repulsive_into<T: Real>(
    pool: &ThreadPool,
    y: &[T],
    params: &FitsneParams,
    raw: &mut [T],
) -> T {
    let n = y.len() / 2;
    assert!(n > 0);
    assert_eq!(raw.len(), 2 * n, "raw buffer must be 2n");
    // Bounding box (shared helper from the quadtree's RootCell).
    let root = crate::quadtree::morton::RootCell::bounding(pool, y);
    let span = 2.0 * root.r_span;
    let n_int = ((span / params.interval_size).ceil() as usize)
        .clamp(params.min_intervals, params.max_intervals);
    let n_grid = n_int * P_NODES; // nodes per dimension
    let h_int = span / n_int as f64; // interval side
    let h_node = h_int / P_NODES as f64; // node spacing
    let x0 = root.cent[0] - root.r_span;
    let y0 = root.cent[1] - root.r_span;
    let m = (2 * n_grid).next_power_of_two(); // FFT size per dim

    // --- Scatter: charge grids for K2 ⊗ (1, x, y) and K1 ⊗ 1.
    // Sequential scatter per grid would race; scatter into per-thread grids
    // and reduce (n_grid² ≤ 384² f64 ≈ 1.2 MB per charge — acceptable).
    let nt = pool.n_threads();
    let gsz = n_grid * n_grid;
    let mut partial = vec![0.0f64; nt * gsz * N_TERMS];
    {
        let ps = SyncSlice::new(&mut partial);
        pool.broadcast(|tid| {
            let (s, e) = crate::parallel::par_for::static_chunk(n, nt, tid);
            // disjoint: per-thread block
            let local = unsafe { ps.slice_mut(tid * gsz * N_TERMS, gsz * N_TERMS) };
            for i in s..e {
                let px = y[2 * i].to_f64();
                let py = y[2 * i + 1].to_f64();
                let (bx, tx) = locate(px, x0, h_int, n_int);
                let (by, ty) = locate(py, y0, h_int, n_int);
                let wx = lagrange_weights(tx);
                let wy = lagrange_weights(ty);
                let charges = [1.0, px, py];
                for kx in 0..P_NODES {
                    let gx = bx * P_NODES + kx;
                    for ky in 0..P_NODES {
                        let gy = by * P_NODES + ky;
                        let w = wx[kx] * wy[ky];
                        let cell = gx * n_grid + gy;
                        for (t, &c) in charges.iter().enumerate() {
                            local[t * gsz + cell] += w * c;
                        }
                    }
                }
            }
        });
    }
    // Reduce thread partials into N_TERMS grids.
    let mut charge_grids = vec![0.0f64; gsz * N_TERMS];
    {
        let cg = SyncSlice::new(&mut charge_grids);
        let partial = &partial;
        parallel_for(pool, gsz * N_TERMS, Schedule::Static, |range| {
            for idx in range {
                let mut acc = 0.0;
                for t in 0..nt {
                    acc += partial[t * gsz * N_TERMS + idx];
                }
                // disjoint: slot idx
                unsafe { *cg.get_mut(idx) = acc };
            }
        });
    }

    // --- Kernel transforms (K1, K2) on the padded M×M grid.
    let kernel = |dsq: f64, squared: bool| {
        let v = 1.0 / (1.0 + dsq);
        if squared {
            v * v
        } else {
            v
        }
    };
    let mut fk1 = build_kernel_grid(pool, n_grid, m, h_node, |d| kernel(d, false));
    let mut fk2 = build_kernel_grid(pool, n_grid, m, h_node, |d| kernel(d, true));
    fft2_inplace(pool, &mut fk1, m, m, false);
    fft2_inplace(pool, &mut fk2, m, m, false);

    // --- Convolve each charge grid with its kernel.
    // potentials: phi_k1_1, phi_k2_1, phi_k2_x, phi_k2_y
    let mut potentials: Vec<Vec<f64>> = Vec::with_capacity(4);
    for (term, use_k2) in [(0usize, false), (0, true), (1, true), (2, true)] {
        let grid = &charge_grids[term * gsz..(term + 1) * gsz];
        let mut padded = vec![Cpx::default(); m * m];
        for gx in 0..n_grid {
            for gy in 0..n_grid {
                padded[gx * m + gy] = Cpx::new(grid[gx * n_grid + gy], 0.0);
            }
        }
        fft2_inplace(pool, &mut padded, m, m, false);
        let fk = if use_k2 { &fk2 } else { &fk1 };
        for (p, k) in padded.iter_mut().zip(fk.iter()) {
            *p = p.mul(*k);
        }
        fft2_inplace(pool, &mut padded, m, m, true);
        let mut pot = vec![0.0f64; gsz];
        for gx in 0..n_grid {
            for gy in 0..n_grid {
                pot[gx * n_grid + gy] = padded[gx * m + gy].re;
            }
        }
        potentials.push(pot);
    }

    // --- Gather potentials back to points and assemble forces + Z.
    let mut z_parts = vec![0.0f64; nt];
    {
        let rs = SyncSlice::new(raw);
        let zs = SyncSlice::new(&mut z_parts);
        let potentials = &potentials;
        pool.broadcast(|tid| {
            let (s, e) = crate::parallel::par_for::static_chunk(n, nt, tid);
            let mut z_local = 0.0;
            for i in s..e {
                let px = y[2 * i].to_f64();
                let py = y[2 * i + 1].to_f64();
                let (bx, tx) = locate(px, x0, h_int, n_int);
                let (by, ty) = locate(py, y0, h_int, n_int);
                let wx = lagrange_weights(tx);
                let wy = lagrange_weights(ty);
                let mut phi = [0.0f64; 4];
                for kx in 0..P_NODES {
                    let gx = bx * P_NODES + kx;
                    for ky in 0..P_NODES {
                        let gy = by * P_NODES + ky;
                        let w = wx[kx] * wy[ky];
                        let cell = gx * n_grid + gy;
                        for (t, p) in potentials.iter().enumerate() {
                            phi[t] += w * p[cell];
                        }
                    }
                }
                // K1 self-term: q(i,i) = 1 → subtract per point.
                z_local += phi[0] - 1.0;
                // raw_i = y_i φ_{K2,1} − φ_{K2,(x,y)}; K2 self-term cancels.
                let fx = px * phi[1] - phi[2];
                let fy = py * phi[1] - phi[3];
                // disjoint: slots 2i, 2i+1
                unsafe {
                    *rs.get_mut(2 * i) = T::from_f64(fx);
                    *rs.get_mut(2 * i + 1) = T::from_f64(fy);
                }
            }
            unsafe { *zs.get_mut(tid) = z_local };
        });
    }
    let z: f64 = z_parts.iter().sum();
    T::from_f64(z.max(f64::MIN_POSITIVE))
}

/// Interval index and relative position of coordinate `v`.
#[inline]
fn locate(v: f64, origin: f64, h: f64, n_int: usize) -> (usize, f64) {
    let rel = (v - origin) / h;
    let b = (rel.floor() as isize).clamp(0, n_int as isize - 1) as usize;
    ((b), (rel - b as f64).clamp(0.0, 1.0))
}

/// Kernel grid with circulant (wrap-around) layout: entry (a, b) holds
/// K(offset(a)·h, offset(b)·h) with offset(a) = a for a < n_grid and a − M for
/// a ≥ M − n_grid + 1 (zero in the unused middle band).
fn build_kernel_grid(
    pool: &ThreadPool,
    n_grid: usize,
    m: usize,
    h: f64,
    kf: impl Fn(f64) -> f64 + Sync,
) -> Vec<Cpx> {
    let offset = |a: usize| -> Option<f64> {
        if a < n_grid {
            Some(a as f64)
        } else if a + n_grid > m {
            Some(a as f64 - m as f64)
        } else {
            None
        }
    };
    let mut grid = vec![Cpx::default(); m * m];
    {
        let gs = SyncSlice::new(&mut grid);
        parallel_for(pool, m, Schedule::Static, |range| {
            for a in range {
                let Some(da) = offset(a) else { continue };
                // disjoint: row a
                let row = unsafe { gs.slice_mut(a * m, m) };
                for (b, slot) in row.iter_mut().enumerate() {
                    let Some(db) = offset(b) else { continue };
                    let dsq = (da * h) * (da * h) + (db * h) * (db * h);
                    *slot = Cpx::new(kf(dsq), 0.0);
                }
            }
        });
    }
    grid
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;
    use crate::gradient::exact::exact_repulsive;

    fn random_y(n: usize, scale: f64, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..2 * n).map(|_| rng.next_gaussian() * scale).collect()
    }

    /// (raw, z) bundle over a locally-owned buffer (`_into` API).
    struct Rep<T: Real> {
        raw: Vec<T>,
        z: T,
    }

    fn fitsne_rep<T: Real>(pool: &ThreadPool, y: &[T], params: &FitsneParams) -> Rep<T> {
        let mut raw = vec![T::ZERO; y.len()];
        let z = fitsne_repulsive_into(pool, y, params, &mut raw);
        Rep { raw, z }
    }

    #[test]
    fn z_close_to_exact() {
        let y = random_y(800, 5.0, 1);
        let pool = ThreadPool::new(4);
        let fit = fitsne_rep(&pool, &y, &FitsneParams::default());
        let (_, z) = exact_repulsive(&pool, &y);
        let rel = (fit.z - z).abs() / z;
        assert!(rel < 0.01, "Z rel error {rel}: {} vs {z}", fit.z);
    }

    #[test]
    fn forces_close_to_exact() {
        let y = random_y(600, 8.0, 2);
        let pool = ThreadPool::new(4);
        let fit = fitsne_rep(&pool, &y, &FitsneParams::default());
        let (want, _) = exact_repulsive(&pool, &y);
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..y.len() {
            num += (fit.raw[i] - want[i]) * (fit.raw[i] - want[i]);
            den += want[i] * want[i];
        }
        // p = 3 Lagrange nodes give a few-percent force accuracy (the
        // gradient-descent path only needs the direction field; Linderman's
        // p=3 setting is in the same regime).
        let rel = (num / den).sqrt();
        assert!(rel < 0.06, "relative RMS {rel}");
    }

    #[test]
    fn tight_cluster_stays_finite() {
        // Early iterations: all points within 1e-4 of origin → single interval.
        let y = random_y(300, 1e-4, 3);
        let pool = ThreadPool::new(2);
        let fit = fitsne_rep(&pool, &y, &FitsneParams::default());
        assert!(fit.raw.iter().all(|v| v.is_finite()));
        assert!(fit.z > 0.0 && fit.z.is_finite());
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let y = random_y(400, 4.0, 4);
        let a = fitsne_rep(&ThreadPool::new(1), &y, &FitsneParams::default());
        let b = fitsne_rep(&ThreadPool::new(8), &y, &FitsneParams::default());
        for i in 0..y.len() {
            assert!(
                (a.raw[i] - b.raw[i]).abs() < 1e-9 * (1.0 + a.raw[i].abs()),
                "idx {i}"
            );
        }
    }

    #[test]
    fn f32_pipeline_works() {
        let y64 = random_y(200, 3.0, 5);
        let y32: Vec<f32> = y64.iter().map(|&v| v as f32).collect();
        let pool = ThreadPool::new(2);
        let fit = fitsne_rep(&pool, &y32, &FitsneParams::default());
        let (want, z) = exact_repulsive(&pool, &y64);
        assert!(((fit.z as f64) - z).abs() / z < 0.02);
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..y64.len() {
            num += (fit.raw[i] as f64 - want[i]).powi(2);
            den += want[i] * want[i];
        }
        assert!((num / den).sqrt() < 0.05);
    }
}
