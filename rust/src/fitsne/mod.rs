//! FIt-SNE: FFT-accelerated interpolation-based repulsive forces
//! (Linderman, Rachh, Hoskins, Steinerberger, Kluger — Nature Methods 2019).
//!
//! The paper's Figures 4–5 and Table 4 compare Acc-t-SNE against FIt-SNE, so
//! the whole engine is built here: the repulsive N-body sums are evaluated by
//! scattering charges onto a regular grid (Lagrange interpolation, [`interp`]),
//! convolving with the squared-Cauchy kernels via FFT ([`fft`]), and gathering
//! back. Replaces the quadtree (steps 3/4/6) inside the [`crate::tsne`]
//! pipeline; KNN/BSP/attractive are shared.
//!
//! Charges and kernels (2-D embedding):
//! - `K1(d) = (1+d²)⁻¹`, charge 1 → Z (after subtracting N self-terms);
//! - `K2(d) = (1+d²)⁻²`, charges (1, x_j, y_j) →
//!   `raw_i = y_i·φ_1(i) − φ_{x,y}(i)` (the un-normalized repulsive force).
//!
//! The engine is stateful: a [`FitsneWorkspace`] owned by the session carries
//! the forward-transformed kernel grids (rebuilt only when the embedding's
//! bounding box changes the grid geometry — the span is snapped to a geometric
//! lattice so a slowly-breathing embedding keeps hitting the cache) and every
//! scatter/charge/pad buffer, so the steady-state step is allocation-free like
//! the BH hot loop. The four convolutions are batched: the real charge grids
//! ride the re/im planes of two complex transforms (real-input packing) and
//! all grids share fused row/column FFT sweeps ([`fft::fft2_batch_inplace`]) —
//! 5 FFT2 passes per step instead of the 10 a stateless step pays.

pub mod fft;
pub mod interp;

use crate::common::float::Real;
use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};
use fft::{fft2_batch_inplace, fft2_inplace, Cpx};
use interp::{lagrange_weights, P_NODES};

/// FIt-SNE tuning knobs (Linderman defaults scaled to this testbed).
#[derive(Clone, Copy, Debug)]
pub struct FitsneParams {
    /// Minimum grid intervals per dimension.
    pub min_intervals: usize,
    /// Cap on intervals (bounds FFT memory: grid = intervals × P_NODES).
    pub max_intervals: usize,
    /// Target interval side length (kernel scale is 1 ⇒ ~1.0).
    pub interval_size: f64,
}

impl Default for FitsneParams {
    fn default() -> Self {
        FitsneParams {
            min_intervals: 50,
            max_intervals: 128,
            interval_size: 1.0,
        }
    }
}

/// Number of charge vectors batched through the K2 convolution.
const N_TERMS: usize = 3; // (1, x, y)

/// Complex pad grids carried through the batched convolution:
/// pad 0 = q₁, pad 1 = qₓ + i·q_y (real-input packing), pad 2 = the K1
/// product (pads 0/1 are reused in place for the two K2 products).
const N_PADS: usize = 3;

/// Span-quantization lattice density. The bounding-box span is rounded up to
/// the next point of the geometric lattice `2^(k/64)` (steps of ~1.1%) before
/// the grid geometry is derived, so the kernel-transform cache keyed on
/// (n_int, m, h_node) keeps hitting while the embedding breathes within a
/// lattice bucket; the ≤1.1% coarser node spacing is far inside the p=3
/// interpolation error budget.
const SPAN_LATTICE_PER_OCTAVE: f64 = 64.0;

/// Round `span` up to the enclosing point of the geometric lattice.
fn quantize_span(span: f64) -> f64 {
    if !(span.is_finite() && span > 0.0) {
        // RootCell::bounding guarantees a finite positive span; keep the
        // fallback total anyway (hostile inputs reach this path via step()).
        return 1.0;
    }
    let k = (span.log2() * SPAN_LATTICE_PER_OCTAVE).ceil();
    (k / SPAN_LATTICE_PER_OCTAVE).exp2()
}

/// Forward-transformed squared-Cauchy kernel grids, valid for one grid
/// geometry. The kernels depend only on (node count, FFT size, node spacing) —
/// not on where the bounding box sits — so they survive every iteration whose
/// quantized span lands in the same lattice bucket.
#[derive(Debug)]
struct CachedKernels {
    n_int: usize,
    m: usize,
    h_node_bits: u64,
    fk1: Vec<Cpx>,
    fk2: Vec<Cpx>,
}

/// Persistent FIt-SNE state: cached kernel transforms plus every buffer the
/// scatter → FFT → gather pipeline touches. One workspace per session; after
/// the first step at a given geometry, [`fitsne_repulsive_into`] performs no
/// heap allocation and no kernel FFT until the geometry changes.
#[derive(Debug, Default)]
pub struct FitsneWorkspace {
    /// Per-thread scatter grids (`nt · gsz · N_TERMS`).
    partial: Vec<f64>,
    /// `N_PADS` concatenated `m × m` complex pad grids.
    pads: Vec<Cpx>,
    /// Per-thread column-FFT scratch (`nt · m`).
    col_scratch: Vec<Cpx>,
    /// Per-thread Z partial sums (`nt`).
    z_parts: Vec<f64>,
    kernels: Option<CachedKernels>,
    kernel_rebuilds: u64,
}

impl FitsneWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// How many times the kernel grids have been rebuilt + re-transformed.
    /// Steady-state iterations at unchanged grid geometry must not move this
    /// counter — the workspace-reuse test and the `fitsne.kernel_rebuilds`
    /// bench key both watch it.
    pub fn kernel_rebuilds(&self) -> u64 {
        self.kernel_rebuilds
    }
}

/// Compute FIt-SNE repulsive accumulations (same contract as the BH
/// kernels in [`crate::gradient::repulsive`]) into a caller-owned `raw`
/// buffer (`2n`, embedding order); returns the ordered-pair normalization Z.
/// The scatter/gather only reads `y[2i..2i+2]`, so the embedding may be
/// morton-resident — the engine is layout-agnostic.
///
/// `ws` carries all buffers and the kernel cache across calls; a mis-sized
/// `raw` is a programming error (debug panic, graceful release no-op), and an
/// empty embedding returns the smallest positive Z instead of panicking.
pub fn fitsne_repulsive_into<T: Real>(
    pool: &ThreadPool,
    y: &[T],
    params: &FitsneParams,
    ws: &mut FitsneWorkspace,
    raw: &mut [T],
) -> T {
    let n = y.len() / 2;
    debug_assert_eq!(raw.len(), 2 * n, "raw buffer must be 2n");
    if n == 0 || raw.len() < 2 * n {
        return T::from_f64(f64::MIN_POSITIVE);
    }
    // Bounding box (shared helper from the quadtree's RootCell), span snapped
    // to the geometric lattice so the kernel cache below can hit.
    let root = crate::quadtree::morton::RootCell::bounding(pool, y);
    let span = quantize_span(2.0 * root.r_span);
    let n_int = ((span / params.interval_size).ceil() as usize)
        .clamp(params.min_intervals, params.max_intervals);
    let n_grid = n_int * P_NODES; // nodes per dimension
    let h_int = span / n_int as f64; // interval side
    let h_node = h_int / P_NODES as f64; // node spacing
    let x0 = root.cent[0] - 0.5 * span;
    let y0 = root.cent[1] - 0.5 * span;
    let m = (2 * n_grid).next_power_of_two(); // FFT size per dim

    let nt = pool.n_threads();
    let gsz = n_grid * n_grid;
    let msz = m * m;
    // Re-zero the reused buffers. `clear` + `resize` only touches the
    // allocator when this geometry needs more capacity than any step before
    // it — the steady-state step is allocation-free.
    ws.partial.clear();
    ws.partial.resize(nt * gsz * N_TERMS, 0.0);
    ws.pads.clear();
    ws.pads.resize(N_PADS * msz, Cpx::default());
    ws.z_parts.clear();
    ws.z_parts.resize(nt, 0.0);
    if ws.col_scratch.len() < nt * m {
        ws.col_scratch.resize(nt * m, Cpx::default());
    }

    // --- Scatter: charge grids for K2 ⊗ (1, x, y) and K1 ⊗ 1.
    // Sequential scatter per grid would race; scatter into per-thread grids
    // and reduce (n_grid² ≤ 384² f64 ≈ 1.2 MB per charge — acceptable).
    {
        let ps = SyncSlice::new(&mut ws.partial);
        pool.broadcast(|tid| {
            let (s, e) = crate::parallel::par_for::static_chunk(n, nt, tid);
            // SAFETY: disjoint — per-thread block
            let local = unsafe { ps.slice_mut(tid * gsz * N_TERMS, gsz * N_TERMS) };
            for i in s..e {
                let px = y[2 * i].to_f64();
                let py = y[2 * i + 1].to_f64();
                let (bx, tx) = locate(px, x0, h_int, n_int);
                let (by, ty) = locate(py, y0, h_int, n_int);
                let wx = lagrange_weights(tx);
                let wy = lagrange_weights(ty);
                let charges = [1.0, px, py];
                for kx in 0..P_NODES {
                    let gx = bx * P_NODES + kx;
                    for ky in 0..P_NODES {
                        let gy = by * P_NODES + ky;
                        let w = wx[kx] * wy[ky];
                        let cell = gx * n_grid + gy;
                        for (t, &c) in charges.iter().enumerate() {
                            local[t * gsz + cell] += w * c;
                        }
                    }
                }
            }
        });
    }
    // Reduce thread partials straight into the complex pads: pad 0 carries
    // q₁ on its real plane, pad 1 packs (qₓ, q_y) as re/im — one inverse
    // transform later recovers both K2 convolutions at once since the
    // kernels are real.
    {
        let ps = SyncSlice::new(&mut ws.pads);
        let partial = &ws.partial;
        parallel_for(pool, gsz, Schedule::Static, |range| {
            for idx in range {
                let mut acc = [0.0f64; N_TERMS];
                for t in 0..nt {
                    let base = t * gsz * N_TERMS;
                    for (term, a) in acc.iter_mut().enumerate() {
                        *a += partial[base + term * gsz + idx];
                    }
                }
                let cell = (idx / n_grid) * m + idx % n_grid;
                // SAFETY: disjoint — slot cell of pads 0 and 1
                unsafe {
                    *ps.get_mut(cell) = Cpx::new(acc[0], 0.0);
                    *ps.get_mut(msz + cell) = Cpx::new(acc[1], acc[2]);
                }
            }
        });
    }

    // --- Kernel transforms (K1, K2) on the padded M×M grid: geometry-keyed
    // cache, rebuilt only when the quantized span changes bucket.
    let h_node_bits = h_node.to_bits();
    let cached = ws
        .kernels
        .as_ref()
        .is_some_and(|k| k.n_int == n_int && k.m == m && k.h_node_bits == h_node_bits);
    if !cached {
        let kernel = |dsq: f64, squared: bool| {
            let v = 1.0 / (1.0 + dsq);
            if squared {
                v * v
            } else {
                v
            }
        };
        let mut fk1 = build_kernel_grid(pool, n_grid, m, h_node, |d| kernel(d, false));
        let mut fk2 = build_kernel_grid(pool, n_grid, m, h_node, |d| kernel(d, true));
        fft2_inplace(pool, &mut fk1, m, m, false);
        fft2_inplace(pool, &mut fk2, m, m, false);
        ws.kernels = Some(CachedKernels { n_int, m, h_node_bits, fk1, fk2 });
        ws.kernel_rebuilds += 1;
    }
    let kernels = ws.kernels.as_ref().expect("kernel cache populated above");

    // --- Convolve: 2 forward transforms (q₁ and the packed qₓ+i·q_y), three
    // pointwise products in one sweep, 3 inverse transforms — all grids fused
    // into shared row/column FFT passes over the pool.
    let pads = &mut ws.pads;
    let col_scratch = &mut ws.col_scratch;
    fft2_batch_inplace(pool, &mut pads[..2 * msz], 2, m, m, false, col_scratch);
    {
        let ps = SyncSlice::new(pads);
        let (fk1, fk2) = (&kernels.fk1, &kernels.fk2);
        parallel_for(pool, msz, Schedule::Static, |range| {
            for i in range {
                // SAFETY: disjoint — slot i of each pad
                unsafe {
                    let a = *ps.get_mut(i);
                    *ps.get_mut(2 * msz + i) = a.mul(fk1[i]);
                    *ps.get_mut(i) = a.mul(fk2[i]);
                    let b = *ps.get_mut(msz + i);
                    *ps.get_mut(msz + i) = b.mul(fk2[i]);
                }
            }
        });
    }
    fft2_batch_inplace(pool, pads, N_PADS, m, m, true, col_scratch);

    // --- Gather potentials back to points and assemble forces + Z.
    // φ_{K1,1} lives on pad 2 (re), φ_{K2,1} on pad 0 (re), φ_{K2,(x,y)} on
    // pad 1 (re, im).
    {
        let rs = SyncSlice::new(raw);
        let zs = SyncSlice::new(&mut ws.z_parts);
        let pads = &*pads;
        pool.broadcast(|tid| {
            let (s, e) = crate::parallel::par_for::static_chunk(n, nt, tid);
            let mut z_local = 0.0;
            for i in s..e {
                let px = y[2 * i].to_f64();
                let py = y[2 * i + 1].to_f64();
                let (bx, tx) = locate(px, x0, h_int, n_int);
                let (by, ty) = locate(py, y0, h_int, n_int);
                let wx = lagrange_weights(tx);
                let wy = lagrange_weights(ty);
                let mut phi = [0.0f64; 4];
                for kx in 0..P_NODES {
                    let gx = bx * P_NODES + kx;
                    for ky in 0..P_NODES {
                        let gy = by * P_NODES + ky;
                        let w = wx[kx] * wy[ky];
                        let cell = gx * m + gy;
                        phi[0] += w * pads[2 * msz + cell].re;
                        let pb = pads[msz + cell];
                        phi[1] += w * pads[cell].re;
                        phi[2] += w * pb.re;
                        phi[3] += w * pb.im;
                    }
                }
                // K1 self-term: q(i,i) = 1 → subtract per point.
                z_local += phi[0] - 1.0;
                // raw_i = y_i φ_{K2,1} − φ_{K2,(x,y)}; K2 self-term cancels.
                let fx = px * phi[1] - phi[2];
                let fy = py * phi[1] - phi[3];
                // SAFETY: disjoint — slots 2i, 2i+1
                unsafe {
                    *rs.get_mut(2 * i) = T::from_f64(fx);
                    *rs.get_mut(2 * i + 1) = T::from_f64(fy);
                }
            }
            // SAFETY: disjoint — one partial-sum slot per tid
            unsafe { *zs.get_mut(tid) = z_local };
        });
    }
    let z: f64 = ws.z_parts.iter().sum();
    T::from_f64(z.max(f64::MIN_POSITIVE))
}

/// Interval index and relative position of coordinate `v`.
#[inline]
fn locate(v: f64, origin: f64, h: f64, n_int: usize) -> (usize, f64) {
    let rel = (v - origin) / h;
    let b = (rel.floor() as isize).clamp(0, n_int as isize - 1) as usize;
    ((b), (rel - b as f64).clamp(0.0, 1.0))
}

/// Kernel grid with circulant (wrap-around) layout: entry (a, b) holds
/// K(offset(a)·h, offset(b)·h) with offset(a) = a for a < n_grid and a − M for
/// a ≥ M − n_grid + 1 (zero in the unused middle band).
fn build_kernel_grid(
    pool: &ThreadPool,
    n_grid: usize,
    m: usize,
    h: f64,
    kf: impl Fn(f64) -> f64 + Sync,
) -> Vec<Cpx> {
    let offset = |a: usize| -> Option<f64> {
        if a < n_grid {
            Some(a as f64)
        } else if a + n_grid > m {
            Some(a as f64 - m as f64)
        } else {
            None
        }
    };
    let mut grid = vec![Cpx::default(); m * m];
    {
        let gs = SyncSlice::new(&mut grid);
        parallel_for(pool, m, Schedule::Static, |range| {
            for a in range {
                let Some(da) = offset(a) else { continue };
                // SAFETY: disjoint — row a
                let row = unsafe { gs.slice_mut(a * m, m) };
                for (b, slot) in row.iter_mut().enumerate() {
                    let Some(db) = offset(b) else { continue };
                    let dsq = (da * h) * (da * h) + (db * h) * (db * h);
                    *slot = Cpx::new(kf(dsq), 0.0);
                }
            }
        });
    }
    grid
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;
    use crate::gradient::exact::exact_repulsive;

    fn random_y(n: usize, scale: f64, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..2 * n).map(|_| rng.next_gaussian() * scale).collect()
    }

    /// (raw, z) bundle over a locally-owned buffer (`_into` API).
    struct Rep<T: Real> {
        raw: Vec<T>,
        z: T,
    }

    fn fitsne_rep<T: Real>(pool: &ThreadPool, y: &[T], params: &FitsneParams) -> Rep<T> {
        let mut ws = FitsneWorkspace::new();
        let mut raw = vec![T::ZERO; y.len()];
        let z = fitsne_repulsive_into(pool, y, params, &mut ws, &mut raw);
        Rep { raw, z }
    }

    #[test]
    fn z_close_to_exact() {
        let y = random_y(800, 5.0, 1);
        let pool = ThreadPool::new(4);
        let fit = fitsne_rep(&pool, &y, &FitsneParams::default());
        let (_, z) = exact_repulsive(&pool, &y);
        let rel = (fit.z - z).abs() / z;
        assert!(rel < 0.01, "Z rel error {rel}: {} vs {z}", fit.z);
    }

    #[test]
    fn forces_close_to_exact() {
        let y = random_y(600, 8.0, 2);
        let pool = ThreadPool::new(4);
        let fit = fitsne_rep(&pool, &y, &FitsneParams::default());
        let (want, _) = exact_repulsive(&pool, &y);
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..y.len() {
            num += (fit.raw[i] - want[i]) * (fit.raw[i] - want[i]);
            den += want[i] * want[i];
        }
        // p = 3 Lagrange nodes give a few-percent force accuracy (the
        // gradient-descent path only needs the direction field; Linderman's
        // p=3 setting is in the same regime).
        let rel = (num / den).sqrt();
        assert!(rel < 0.06, "relative RMS {rel}");
    }

    #[test]
    fn tight_cluster_stays_finite() {
        // Early iterations: all points within 1e-4 of origin → single interval.
        let y = random_y(300, 1e-4, 3);
        let pool = ThreadPool::new(2);
        let fit = fitsne_rep(&pool, &y, &FitsneParams::default());
        assert!(fit.raw.iter().all(|v| v.is_finite()));
        assert!(fit.z > 0.0 && fit.z.is_finite());
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let y = random_y(400, 4.0, 4);
        let a = fitsne_rep(&ThreadPool::new(1), &y, &FitsneParams::default());
        let b = fitsne_rep(&ThreadPool::new(8), &y, &FitsneParams::default());
        for i in 0..y.len() {
            assert!(
                (a.raw[i] - b.raw[i]).abs() < 1e-9 * (1.0 + a.raw[i].abs()),
                "idx {i}"
            );
        }
    }

    #[test]
    fn f32_pipeline_works() {
        let y64 = random_y(200, 3.0, 5);
        let y32: Vec<f32> = y64.iter().map(|&v| v as f32).collect();
        let pool = ThreadPool::new(2);
        let fit = fitsne_rep(&pool, &y32, &FitsneParams::default());
        let (want, z) = exact_repulsive(&pool, &y64);
        assert!(((fit.z as f64) - z).abs() / z < 0.02);
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..y64.len() {
            num += (fit.raw[i] as f64 - want[i]).powi(2);
            den += want[i] * want[i];
        }
        assert!((num / den).sqrt() < 0.05);
    }

    #[test]
    fn empty_embedding_is_a_graceful_no_op() {
        let pool = ThreadPool::new(2);
        let mut ws = FitsneWorkspace::new();
        let y: Vec<f64> = Vec::new();
        let mut raw: Vec<f64> = Vec::new();
        let z = fitsne_repulsive_into(&pool, &y, &FitsneParams::default(), &mut ws, &mut raw);
        assert!(z > 0.0 && z.is_finite());
        assert_eq!(ws.kernel_rebuilds(), 0);
    }

    #[test]
    fn span_quantization_is_monotone_and_enclosing() {
        let mut prev = 0.0;
        for e in -40..40 {
            for frac in [1.0, 1.003, 1.01, 1.3, 1.7] {
                let span = (e as f64).exp2() * frac;
                let q = quantize_span(span);
                // ~half an ulp of lattice rounding is tolerable: locate() clamps.
                assert!(q >= span * (1.0 - 1e-12), "span {span}: q {q}");
                assert!(q <= span * 1.02, "span {span}: q {q} too coarse");
                assert!(q >= prev, "lattice must be monotone");
                prev = q;
            }
        }
        // Hostile spans fall back to a finite bucket.
        assert_eq!(quantize_span(f64::NAN), 1.0);
        assert_eq!(quantize_span(f64::INFINITY), 1.0);
        assert_eq!(quantize_span(0.0), 1.0);
    }

    #[test]
    fn workspace_reuse_is_allocation_free_and_caches_kernels() {
        let y = random_y(500, 6.0, 7);
        let pool = ThreadPool::new(4);
        let params = FitsneParams::default();
        let mut ws = FitsneWorkspace::new();
        let mut raw1 = vec![0.0f64; y.len()];
        let z1 = fitsne_repulsive_into(&pool, &y, &params, &mut ws, &mut raw1);
        assert_eq!(ws.kernel_rebuilds(), 1, "first step builds the kernels once");
        let fingerprint = (
            ws.partial.as_ptr(),
            ws.partial.capacity(),
            ws.pads.as_ptr(),
            ws.pads.capacity(),
            ws.col_scratch.as_ptr(),
            ws.col_scratch.capacity(),
        );
        // Steady state: same geometry → no kernel rebuild, no reallocation,
        // and a bit-identical result (the cached transform is the same data
        // the rebuild would produce).
        let mut raw2 = vec![0.0f64; y.len()];
        let z2 = fitsne_repulsive_into(&pool, &y, &params, &mut ws, &mut raw2);
        assert_eq!(ws.kernel_rebuilds(), 1, "unchanged geometry must hit the cache");
        assert_eq!(
            fingerprint,
            (
                ws.partial.as_ptr(),
                ws.partial.capacity(),
                ws.pads.as_ptr(),
                ws.pads.capacity(),
                ws.col_scratch.as_ptr(),
                ws.col_scratch.capacity(),
            ),
            "steady-state step must not reallocate any workspace buffer"
        );
        assert_eq!(z1, z2);
        assert_eq!(raw1, raw2);
        // Small drift inside the same lattice bucket still hits the cache.
        let y_drift: Vec<f64> = y.iter().map(|v| v * 1.0001).collect();
        fitsne_repulsive_into(&pool, &y_drift, &params, &mut ws, &mut raw2);
        assert_eq!(ws.kernel_rebuilds(), 1, "sub-bucket drift must not rebuild");
        // A genuine geometry change (span × 4) rebuilds exactly once.
        let y_big: Vec<f64> = y.iter().map(|v| v * 4.0).collect();
        fitsne_repulsive_into(&pool, &y_big, &params, &mut ws, &mut raw2);
        assert_eq!(ws.kernel_rebuilds(), 2, "a new lattice bucket rebuilds the kernels");
    }

    #[test]
    fn workspace_reuse_matches_fresh_workspace() {
        // A workspace that has seen a different geometry must produce the
        // same bits as a fresh one (stale pads/kernels fully masked).
        let pool = ThreadPool::new(4);
        let params = FitsneParams::default();
        let y_a = random_y(300, 12.0, 8);
        let y_b = random_y(450, 3.0, 9);
        let mut ws = FitsneWorkspace::new();
        let mut raw = vec![0.0f64; y_a.len()];
        fitsne_repulsive_into(&pool, &y_a, &params, &mut ws, &mut raw);
        let mut reused = vec![0.0f64; y_b.len()];
        let z_reused = fitsne_repulsive_into(&pool, &y_b, &params, &mut ws, &mut reused);
        let fresh = fitsne_rep(&pool, &y_b, &params);
        assert_eq!(z_reused, fresh.z);
        assert_eq!(reused, fresh.raw);
    }
}
