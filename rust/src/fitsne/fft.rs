//! FFT substrate for the FIt-SNE baseline (the paper compares against
//! Linderman et al.'s FFT-interpolation t-SNE; no FFTW offline, so we own an
//! iterative radix-2 Cooley-Tukey complex FFT and a row/column-parallel 2-D
//! transform).

use crate::parallel::{parallel_for, Schedule, SyncSlice, ThreadPool};

/// Minimal complex number (no external num-complex dependency).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cpx {
    pub re: f64,
    pub im: f64,
}

impl Cpx {
    #[inline(always)]
    pub fn new(re: f64, im: f64) -> Self {
        Cpx { re, im }
    }

    #[inline(always)]
    pub fn mul(self, o: Cpx) -> Cpx {
        Cpx::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }

    #[inline(always)]
    pub fn add(self, o: Cpx) -> Cpx {
        Cpx::new(self.re + o.re, self.im + o.im)
    }

    #[inline(always)]
    pub fn sub(self, o: Cpx) -> Cpx {
        Cpx::new(self.re - o.re, self.im - o.im)
    }
}

/// In-place radix-2 FFT. `data.len()` must be a power of two.
/// `invert = true` computes the inverse transform including the 1/n scale.
///
/// A non-power-of-two length is a programming error (every caller derives the
/// size via `next_power_of_two`): debug builds panic, release builds leave the
/// buffer untouched instead of corrupting it — the FIt path is panic-free.
pub fn fft_inplace(data: &mut [Cpx], invert: bool) {
    let n = data.len();
    debug_assert!(n.is_power_of_two(), "FFT length must be a power of two");
    if !n.is_power_of_two() {
        return;
    }
    if n <= 1 {
        return;
    }
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            data.swap(i, j);
        }
    }
    let sign = if invert { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let wlen = Cpx::new(ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut w = Cpx::new(1.0, 0.0);
            for k in 0..len / 2 {
                let u = data[i + k];
                let v = data[i + k + len / 2].mul(w);
                data[i + k] = u.add(v);
                data[i + k + len / 2] = u.sub(v);
                w = w.mul(wlen);
            }
            i += len;
        }
        len <<= 1;
    }
    if invert {
        let inv = 1.0 / n as f64;
        for d in data.iter_mut() {
            d.re *= inv;
            d.im *= inv;
        }
    }
}

/// In-place 2-D FFT of a row-major `rows × cols` grid (both powers of two).
/// Rows are transformed in parallel, then columns (via transpose-free strided
/// copies, parallel over columns).
pub fn fft2_inplace(pool: &ThreadPool, data: &mut [Cpx], rows: usize, cols: usize, invert: bool) {
    assert_eq!(data.len(), rows * cols);
    // rows
    {
        let ds = SyncSlice::new(data);
        parallel_for(pool, rows, Schedule::Dynamic { grain: 4 }, |range| {
            for r in range {
                // SAFETY: disjoint — row r
                let row = unsafe { ds.slice_mut(r * cols, cols) };
                fft_inplace(row, invert);
            }
        });
    }
    // columns
    {
        let ds = SyncSlice::new(data);
        parallel_for(pool, cols, Schedule::Dynamic { grain: 4 }, |range| {
            let mut buf = vec![Cpx::default(); rows];
            for c in range {
                for r in 0..rows {
                    // SAFETY: read-only overlap is fine; writes below are disjoint per column
                    buf[r] = unsafe { *ds.get_mut(r * cols + c) };
                }
                fft_inplace(&mut buf, invert);
                for r in 0..rows {
                    // SAFETY: disjoint — column c slots
                    unsafe { *ds.get_mut(r * cols + c) = buf[r] };
                }
            }
        });
    }
}

/// In-place 2-D FFT of `n_grids` concatenated row-major `rows × cols` grids,
/// fused into single pool dispatches: one parallel sweep over all
/// `n_grids · rows` rows, then one over all `n_grids · cols` columns. The pool
/// cannot nest broadcasts, so batching independent transforms into shared
/// sweeps is how the FIt-SNE convolution pipeline runs its grids "in
/// parallel" — and it halves the number of barriers versus sequential
/// [`fft2_inplace`] calls.
///
/// `col_scratch` is caller-owned per-thread column storage
/// (`pool.n_threads() * rows` entries) so the steady-state hot loop performs
/// no heap allocation; an undersized scratch is a programming error (debug
/// panic, release no-op).
pub fn fft2_batch_inplace(
    pool: &ThreadPool,
    data: &mut [Cpx],
    n_grids: usize,
    rows: usize,
    cols: usize,
    invert: bool,
    col_scratch: &mut [Cpx],
) {
    let nt = pool.n_threads();
    debug_assert_eq!(data.len(), n_grids * rows * cols);
    debug_assert!(col_scratch.len() >= nt * rows, "column scratch must hold nt*rows entries");
    if data.len() != n_grids * rows * cols || col_scratch.len() < nt * rows {
        return;
    }
    // Rows: grids are contiguous, so the batch is just n_grids·rows
    // independent rows of `cols` entries each.
    {
        let ds = SyncSlice::new(data);
        parallel_for(pool, n_grids * rows, Schedule::Dynamic { grain: 4 }, |range| {
            for r in range {
                // SAFETY: disjoint — row r of the concatenated grids
                let row = unsafe { ds.slice_mut(r * cols, cols) };
                fft_inplace(row, invert);
            }
        });
    }
    // Columns: statically chunk the n_grids·cols columns over the pool; each
    // thread strides through its columns via its private scratch slice, so
    // the sweep is deterministic and allocation-free.
    {
        let ds = SyncSlice::new(data);
        let cs = SyncSlice::new(col_scratch);
        pool.broadcast(|tid| {
            let (s, e) = crate::parallel::par_for::static_chunk(n_grids * cols, nt, tid);
            // SAFETY: disjoint — per-thread scratch block
            let buf = unsafe { cs.slice_mut(tid * rows, rows) };
            for ci in s..e {
                let (g, c) = (ci / cols, ci % cols);
                let base = g * rows * cols;
                for r in 0..rows {
                    // SAFETY: read-only overlap is fine; writes below are disjoint per column
                    buf[r] = unsafe { *ds.get_mut(base + r * cols + c) };
                }
                fft_inplace(buf, invert);
                for r in 0..rows {
                    // SAFETY: disjoint — column c of grid g
                    unsafe { *ds.get_mut(base + r * cols + c) = buf[r] };
                }
            }
        });
    }
}

/// Circular 2-D convolution via FFT: `out = ifft2(fft2(a) ∘ fft2(b))`.
/// Both grids `rows × cols`, powers of two. Used by tests; the FIt-SNE path
/// caches the kernel transform across charge vectors instead.
pub fn convolve2(pool: &ThreadPool, a: &[Cpx], b: &[Cpx], rows: usize, cols: usize) -> Vec<Cpx> {
    let mut fa = a.to_vec();
    let mut fb = b.to_vec();
    fft2_inplace(pool, &mut fa, rows, cols, false);
    fft2_inplace(pool, &mut fb, rows, cols, false);
    for (x, y) in fa.iter_mut().zip(fb.iter()) {
        *x = x.mul(*y);
    }
    fft2_inplace(pool, &mut fa, rows, cols, true);
    fa
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::rng::Rng;

    fn naive_dft(data: &[Cpx], invert: bool) -> Vec<Cpx> {
        let n = data.len();
        let sign = if invert { 1.0 } else { -1.0 };
        let mut out = vec![Cpx::default(); n];
        for k in 0..n {
            let mut acc = Cpx::default();
            for t in 0..n {
                let ang = sign * 2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
                acc = acc.add(data[t].mul(Cpx::new(ang.cos(), ang.sin())));
            }
            out[k] = if invert {
                Cpx::new(acc.re / n as f64, acc.im / n as f64)
            } else {
                acc
            };
        }
        out
    }

    #[test]
    fn matches_naive_dft() {
        let mut rng = Rng::new(1);
        for n in [1usize, 2, 8, 64, 256] {
            let data: Vec<Cpx> =
                (0..n).map(|_| Cpx::new(rng.next_gaussian(), rng.next_gaussian())).collect();
            let mut fast = data.clone();
            fft_inplace(&mut fast, false);
            let slow = naive_dft(&data, false);
            for i in 0..n {
                assert!((fast[i].re - slow[i].re).abs() < 1e-8, "n={n} i={i}");
                assert!((fast[i].im - slow[i].im).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn roundtrip_identity() {
        let mut rng = Rng::new(2);
        let data: Vec<Cpx> = (0..128).map(|_| Cpx::new(rng.next_gaussian(), 0.0)).collect();
        let mut x = data.clone();
        fft_inplace(&mut x, false);
        fft_inplace(&mut x, true);
        for i in 0..data.len() {
            assert!((x[i].re - data[i].re).abs() < 1e-12);
            assert!(x[i].im.abs() < 1e-12);
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_power_of_two() {
        let mut d = vec![Cpx::default(); 12];
        fft_inplace(&mut d, false);
    }

    #[cfg(not(debug_assertions))]
    #[test]
    fn non_power_of_two_is_a_release_no_op() {
        // Release builds must not corrupt the buffer (or loop forever) on the
        // invalid length — the data comes back untouched.
        let d: Vec<Cpx> = (0..12).map(|i| Cpx::new(i as f64, -(i as f64))).collect();
        let mut x = d.clone();
        fft_inplace(&mut x, false);
        assert_eq!(x, d);
    }

    #[test]
    fn fft2_roundtrip() {
        let mut rng = Rng::new(3);
        let (r, c) = (16, 32);
        let data: Vec<Cpx> = (0..r * c).map(|_| Cpx::new(rng.next_gaussian(), 0.0)).collect();
        let pool = ThreadPool::new(4);
        let mut x = data.clone();
        fft2_inplace(&pool, &mut x, r, c, false);
        fft2_inplace(&pool, &mut x, r, c, true);
        for i in 0..data.len() {
            assert!((x[i].re - data[i].re).abs() < 1e-11);
        }
    }

    #[test]
    fn convolution_matches_direct() {
        let mut rng = Rng::new(4);
        let (r, c) = (8, 8);
        let a: Vec<Cpx> = (0..r * c).map(|_| Cpx::new(rng.next_gaussian(), 0.0)).collect();
        let b: Vec<Cpx> = (0..r * c).map(|_| Cpx::new(rng.next_gaussian(), 0.0)).collect();
        let pool = ThreadPool::new(2);
        let got = convolve2(&pool, &a, &b, r, c);
        // direct circular convolution
        for or in 0..r {
            for oc in 0..c {
                let mut acc = 0.0;
                for ir in 0..r {
                    for ic in 0..c {
                        let br = (or + r - ir) % r;
                        let bc = (oc + c - ic) % c;
                        acc += a[ir * c + ic].re * b[br * c + bc].re;
                    }
                }
                let g = got[or * c + oc].re;
                assert!((g - acc).abs() < 1e-9, "({or},{oc}): {g} vs {acc}");
            }
        }
    }

    #[test]
    fn batch_matches_per_grid_fft2() {
        let mut rng = Rng::new(6);
        let (r, c, n_grids) = (16, 8, 3);
        let pool = ThreadPool::new(4);
        for invert in [false, true] {
            let data: Vec<Cpx> = (0..n_grids * r * c)
                .map(|_| Cpx::new(rng.next_gaussian(), rng.next_gaussian()))
                .collect();
            let mut batched = data.clone();
            let mut scratch = vec![Cpx::default(); pool.n_threads() * r];
            fft2_batch_inplace(&pool, &mut batched, n_grids, r, c, invert, &mut scratch);
            for g in 0..n_grids {
                let mut single = data[g * r * c..(g + 1) * r * c].to_vec();
                fft2_inplace(&pool, &mut single, r, c, invert);
                for i in 0..r * c {
                    let got = batched[g * r * c + i];
                    let want = single[i];
                    assert!(
                        (got.re - want.re).abs() < 1e-12 && (got.im - want.im).abs() < 1e-12,
                        "grid {g} slot {i} (invert={invert}): {got:?} vs {want:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn parseval_energy_preserved() {
        let mut rng = Rng::new(5);
        let data: Vec<Cpx> =
            (0..256).map(|_| Cpx::new(rng.next_gaussian(), rng.next_gaussian())).collect();
        let time_e: f64 = data.iter().map(|c| c.re * c.re + c.im * c.im).sum();
        let mut f = data.clone();
        fft_inplace(&mut f, false);
        let freq_e: f64 = f.iter().map(|c| c.re * c.re + c.im * c.im).sum::<f64>() / 256.0;
        assert!((time_e - freq_e).abs() < 1e-8 * time_e);
    }
}
