//! Million-point end-to-end fit: the scale path the approximate-KNN subsystem
//! exists for. Exact KNN is O(n²·d) — at n = 1M that is ~10¹³ distance ops and
//! hours of wall time; the HNSW build + search is the only practical route.
//!
//! Pipeline (run with `cargo run --release --example million_points`):
//!   1. synthesize a 1M-point Gaussian mixture (32 clusters, d = 16);
//!   2. build an approximate KNN graph (`KnnGraph::build_approximate`,
//!      default HNSW params: M = 16, ef_construction = 200, ef_search = 64
//!      — ≥ 0.9 recall@k on clustered data, see BENCH_knn.json);
//!   3. round-trip the graph through the persistence layer (save → load →
//!      fingerprint check) — the artifact a perplexity sweep would reuse;
//!   4. BSP-only affinity fit from the loaded graph (no second KNN pass);
//!   5. descend with the plan `StagePlan::auto_for(n)` picks — FFT repulsion
//!      and the HNSW engine above the measured crossover;
//!   6. report per-stage times and a neighbor-preservation count on a
//!      subsample (exact preservation at 1M would itself be O(n²)).
//!
//! Size and iteration count are env-tunable so CI smoke runs stay cheap:
//!   ACC_TSNE_MILLION_N      point count   (default 1_000_000)
//!   ACC_TSNE_MILLION_ITERS  iterations    (default 250)

use std::time::Instant;

use acc_tsne::common::timer::Step;
use acc_tsne::data::synthetic::gaussian_mixture;
use acc_tsne::knn::hnsw::HnswParams;
use acc_tsne::metrics::neighbor_preservation;
use acc_tsne::parallel::ThreadPool;
use acc_tsne::tsne::{Affinities, KnnGraph, StagePlan, TsneConfig, TsneSession};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let n = env_usize("ACC_TSNE_MILLION_N", 1_000_000);
    let iters = env_usize("ACC_TSNE_MILLION_ITERS", 250);
    let (d, clusters, perplexity) = (16usize, 32usize, 30.0f64);
    let k = (3.0 * perplexity) as usize; // the ⌊3u⌋ neighbor budget
    let pool = ThreadPool::with_all_cores();
    println!("million-point fit: n={n} d={d} k={k} iters={iters} threads={}", pool.n_threads());

    let t = Instant::now();
    let ds = gaussian_mixture::<f64>(n, d, clusters, 6.0, 4242);
    println!(
        "[{:8.2}s] dataset: {} clusters of ~{} points",
        t.elapsed().as_secs_f64(),
        clusters,
        n / clusters
    );

    // Approximate KNN graph — the tentpole. Deterministic for the seed at any
    // thread count, rows ascending (distance, index), so the ⌊3u⌋-prefix
    // re-fit contract below holds for this build.
    let graph =
        KnnGraph::build_approximate(&pool, &ds.points, ds.n, ds.d, k, &HnswParams::default())
            .expect("finite synthetic data builds");
    println!(
        "[{:8.2}s] KNN graph: engine {} ({:.1}s in Step::Knn)",
        t.elapsed().as_secs_f64(),
        graph.engine(),
        graph.step_times().get(Step::Knn)
    );

    // Persist → reload → verify: the exact artifact flow a perplexity sweep
    // uses (`--save-knn` / `--knn`), engine metadata included.
    let path = std::env::temp_dir().join(format!("acc_tsne_million_{}.knn", std::process::id()));
    graph.save(&path).expect("temp dir is writable");
    let loaded = KnnGraph::<f64>::load(&path).expect("round-trip");
    std::fs::remove_file(&path).ok();
    loaded.verify_source(&ds.points, ds.n, ds.d).expect("fingerprint matches");
    assert_eq!(loaded.engine(), graph.engine(), "engine metadata survives persistence");
    println!(
        "[{:8.2}s] graph round-tripped through disk (engine metadata intact)",
        t.elapsed().as_secs_f64()
    );

    // BSP-only affinity fit from the loaded graph — no second KNN pass.
    let plan = StagePlan::auto_for(ds.n);
    println!(
        "[{:8.2}s] plan: {} repulsion, {} KNN engine",
        t.elapsed().as_secs_f64(),
        if plan.fft_repulsion { "FFT" } else { "Barnes-Hut" },
        plan.knn_engine.name()
    );
    let aff = Affinities::from_knn(&pool, &loaded, perplexity, &plan).expect("k >= 3u");

    let cfg = TsneConfig {
        n_iter: iters,
        seed: 4242,
        n_threads: pool.n_threads(),
        perplexity,
        ..TsneConfig::default()
    };
    let mut sess = TsneSession::new(&aff, plan, cfg).expect("auto plan is valid");
    sess.run(iters);
    let mut r = sess.finish();
    // Fold the KNN (in-memory build; the loaded artifact's times are empty
    // by contract) and BSP (affinity fit) wall times into the gradient-phase
    // times so the percentages cover the whole pipeline.
    r.step_times.merge(graph.step_times());
    r.step_times.merge(aff.step_times());
    println!(
        "[{:8.2}s] descent done: KL = {:.4} after {} iters",
        t.elapsed().as_secs_f64(),
        r.kl_divergence,
        r.n_iter
    );
    println!("per-stage share of {:.1}s total:", r.step_times.total());
    for (step, pct) in r.step_times.percentages() {
        println!("  {:<10} {:6.2}% ({:.2}s)", step.name(), pct, r.step_times.get(step));
    }

    // Neighborhood preservation on a strided subsample (exact at 1M would be
    // O(n²)). The count answers "did the approximate graph still place the
    // clusters?" — on this mixture expect well above the 1/32 random floor.
    let sub = ds.n.min(5_000);
    let stride = ds.n / sub;
    let mut hi = Vec::with_capacity(sub * ds.d);
    let mut lo = Vec::with_capacity(sub * 2);
    for s in 0..sub {
        let i = s * stride;
        hi.extend_from_slice(&ds.points[i * ds.d..(i + 1) * ds.d]);
        lo.extend_from_slice(&r.embedding[2 * i..2 * i + 2]);
    }
    let kq = 10usize;
    let np = neighbor_preservation(&pool, &hi, sub, ds.d, &lo, kq);
    println!(
        "neighbor preservation @k={kq} on {sub}-point subsample: {:.3} \
         (~{:.0} of each point's {kq} high-dim neighbors kept; random ≈ {:.3})",
        np,
        np * kq as f64,
        kq as f64 / sub as f64
    );
    println!("total wall time: {:.2}s", t.elapsed().as_secs_f64());
}
