#!/usr/bin/env python3
"""Fails-soft bench trend check.

Compares BENCH_*.json snapshots (written by `cargo bench --bench
bench_micro_kernels`) against committed baselines in bench_baselines/.
A timing field (any numeric key ending in `_s`, nested objects included)
that is more than REGRESSION_THRESHOLD above its baseline emits a GitHub
`::warning::` annotation. The script never fails the build: CI runners are
noisy and the trend is advisory (see ROADMAP "wire it into a trend check").

Refresh a baseline by copying the snapshot from a trusted run:
    cp rust/BENCH_repulsive.json bench_baselines/

With no arguments the full snapshot set (DEFAULT_SNAPSHOTS) is checked.
"""
import json
import os
import sys

REGRESSION_THRESHOLD = 1.20  # warn if >20% slower than baseline
BASELINE_DIR = "bench_baselines"
DEFAULT_SNAPSHOTS = [
    "rust/BENCH_repulsive.json",
    "rust/BENCH_gradient_loop.json",
    "rust/BENCH_fitsne.json",
    "rust/BENCH_knn.json",
    "rust/BENCH_serving.json",
]


def is_timing_key(key):
    """A key the trend comparator treats as a duration (higher = worse).

    Durations end in `_s` by convention. Rates end in `per_s` (e.g. the
    serving group's `sessions_per_s`, where HIGHER is better) — they share
    the suffix but must not be compared as timings, so they are exempt.
    """
    return key.endswith("_s") and not key.endswith("per_s")


def flatten(d, prefix=""):
    out = {}
    for k, v in d.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(flatten(v, key + "."))
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            out[key] = float(v)
    return out


def main(paths):
    warned = 0
    for path in paths:
        if not os.path.exists(path):
            print(f"::warning::{path} missing (bench did not produce it)")
            warned += 1
            continue
        with open(path) as f:
            cur = flatten(json.load(f))
        base_path = os.path.join(BASELINE_DIR, os.path.basename(path))
        if not os.path.exists(base_path):
            print(f"{path}: no baseline at {base_path} — current values (commit one to start the trend):")
            for k, v in sorted(cur.items()):
                print(f"  {k} = {v:.6g}")
            continue
        with open(base_path) as f:
            base = flatten(json.load(f))
        # Keys present in the current snapshot but not in the baseline are
        # tolerated, not flagged: new sweeps (e.g. the adopt_sweep.* keys of
        # BENCH_gradient_loop.json) appear before any baseline records them.
        new_keys = [k for k in sorted(cur) if is_timing_key(k) and k not in base]
        if new_keys:
            print(
                f"{path}: {len(new_keys)} key(s) without a baseline yet "
                f"(refresh {base_path} to start their trend): " + ", ".join(new_keys)
            )
        for k in sorted(base):
            if not is_timing_key(k) or k not in cur or base[k] <= 0:
                continue
            ratio = cur[k] / base[k]
            if ratio > REGRESSION_THRESHOLD:
                print(
                    f"::warning title=bench regression::{path}:{k} is "
                    f"{ratio:.2f}x baseline ({cur[k]:.4g}s vs {base[k]:.4g}s)"
                )
                warned += 1
            else:
                print(f"ok {path}:{k} {ratio:.2f}x baseline")
    print(f"bench trend check done (fails-soft, {warned} warning(s))")
    return 0  # advisory: never fail the build


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or DEFAULT_SNAPSHOTS))
