"""Unit tests for scripts/check_workflows.py (run by the same cheap early CI
step as test_bench_trend.py).

The linter is a hard gate, so every scenario asserts on the return code as
well as the emitted ::error annotations.
"""
import contextlib
import io
import os
import tempfile
import unittest

import check_workflows


GOOD_CI = """\
name: CI
on:
  push:
    branches: [main]
jobs:
  build:
    runs-on: ubuntu-latest
    steps:
      - uses: actions/checkout@v4
"""

GOOD_DOWNSTREAM = """\
name: Promote
on:
  workflow_dispatch:
  workflow_run:
    workflows: [CI]
    types: [completed]
jobs:
  promote:
    runs-on: ubuntu-latest
    steps:
      - run: echo promote
"""


def full_ci(**overrides):
    """A ci.yml document containing every job the skeleton check requires."""
    lines = ["name: CI", "on: push", "jobs:"]
    for job_id in sorted(check_workflows.REQUIRED_JOBS["ci.yml"]):
        if overrides.get(job_id) == "omit":
            continue
        lines += [
            f"  {job_id}:",
            "    runs-on: ubuntu-latest",
            "    steps:",
            "      - run: echo ok",
        ]
    return "\n".join(lines) + "\n"


class CheckWorkflowsCase(unittest.TestCase):
    def setUp(self):
        self._tmp = tempfile.TemporaryDirectory()
        self.dir = self._tmp.name

    def tearDown(self):
        self._tmp.cleanup()

    def write(self, fname, text):
        with open(os.path.join(self.dir, fname), "w") as f:
            f.write(text)

    def run_main(self):
        out = io.StringIO()
        with contextlib.redirect_stdout(out):
            rc = check_workflows.main([self.dir])
        return rc, out.getvalue()

    def test_valid_workflows_pass(self):
        self.write("build.yml", GOOD_CI)
        self.write("promote.yml", GOOD_DOWNSTREAM)
        rc, out = self.run_main()
        self.assertEqual(rc, 0, out)
        self.assertNotIn("::error", out)
        self.assertIn("2 file(s), 0 error(s)", out)

    def test_yaml_11_on_key_parses_as_boolean_true(self):
        # The linter's whole reason for the ON_KEYS tuple: safe_load turns
        # the `on:` KEY into the boolean True, and a naive doc["on"] lookup
        # would report every single workflow as trigger-less.
        import yaml

        doc = yaml.safe_load(GOOD_CI)
        self.assertNotIn("on", doc)
        self.assertIn(True, doc)
        self.assertIsNotNone(check_workflows.trigger_block(doc))

    def test_parse_error_is_fatal(self):
        self.write("broken.yml", "name: X\non: [unclosed\n")
        rc, out = self.run_main()
        self.assertEqual(rc, 1)
        self.assertIn("::error", out)
        self.assertIn("YAML parse error", out)

    def test_missing_name_is_fatal(self):
        self.write("anon.yml", GOOD_CI.replace("name: CI\n", ""))
        rc, out = self.run_main()
        self.assertEqual(rc, 1)
        self.assertIn("missing workflow `name:`", out)

    def test_missing_trigger_is_fatal(self):
        self.write("build.yml", "name: CI\njobs:\n  b:\n    runs-on: x\n    steps:\n      - run: a\n")
        rc, out = self.run_main()
        self.assertEqual(rc, 1)
        self.assertIn("missing trigger block", out)

    def test_job_without_runs_on_or_steps_is_fatal(self):
        self.write("build.yml", "name: CI\non: push\njobs:\n  b:\n    timeout-minutes: 5\n")
        rc, out = self.run_main()
        self.assertEqual(rc, 1)
        self.assertIn("no `runs-on:`", out)
        self.assertIn("no `steps:`", out)

    def test_reusable_workflow_job_needs_no_steps(self):
        self.write("build.yml", GOOD_CI)
        self.write(
            "reuse.yml",
            "name: Reuse\non: push\njobs:\n  call:\n    uses: ./.github/workflows/ci.yml\n",
        )
        rc, out = self.run_main()
        self.assertEqual(rc, 0, out)

    def test_workflow_run_reference_to_missing_workflow_is_fatal(self):
        # The regression this linter exists for: rename `name: CI` and the
        # promote workflow's `workflow_run.workflows: [CI]` silently never
        # fires again. The reference check turns that into a red X.
        self.write("build.yml", GOOD_CI.replace("name: CI", "name: Continuous Integration"))
        self.write("promote.yml", GOOD_DOWNSTREAM)
        rc, out = self.run_main()
        self.assertEqual(rc, 1)
        self.assertIn("workflow_run references `CI`", out)
        self.assertIn("Continuous Integration", out, "known names are listed to aid the fix")

    def test_workflow_run_reference_as_plain_string(self):
        self.write("build.yml", GOOD_CI)
        self.write(
            "promote.yml",
            GOOD_DOWNSTREAM.replace("workflows: [CI]", "workflows: Nope"),
        )
        rc, out = self.run_main()
        self.assertEqual(rc, 1)
        self.assertIn("workflow_run references `Nope`", out)

    def test_missing_directory_is_fatal(self):
        out = io.StringIO()
        with contextlib.redirect_stdout(out):
            rc = check_workflows.main([os.path.join(self.dir, "nope")])
        self.assertEqual(rc, 1)
        self.assertIn("does not exist", out.getvalue())

    def test_empty_directory_is_fatal(self):
        rc, out = self.run_main()
        self.assertEqual(rc, 1)
        self.assertIn("no workflow files", out)

    def test_ci_skeleton_complete_passes(self):
        self.write("ci.yml", full_ci())
        rc, out = self.run_main()
        self.assertEqual(rc, 0, out)

    def test_ci_skeleton_missing_job_is_fatal(self):
        # Deleting a required job (here the tsan tier) must be a red X, not a
        # silent weakening of the gate.
        self.write("ci.yml", full_ci(tsan="omit"))
        rc, out = self.run_main()
        self.assertEqual(rc, 1)
        self.assertIn("required job `tsan` is missing", out)

    def test_ci_skeleton_does_not_constrain_other_files(self):
        # The skeleton is keyed by basename: a workflow that happens to have
        # `name: CI` but lives in another file is unconstrained.
        self.write("build.yml", GOOD_CI)
        rc, out = self.run_main()
        self.assertEqual(rc, 0, out)

    def test_ci_skeleton_allows_extra_jobs(self):
        self.write("ci.yml", full_ci() + "  extra:\n    runs-on: x\n    steps:\n      - run: a\n")
        rc, out = self.run_main()
        self.assertEqual(rc, 0, out)

    def test_repo_workflows_lint_clean(self):
        # The real tree must satisfy its own linter (the CI step runs this
        # same check from the repo root).
        repo_dir = os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            ".github",
            "workflows",
        )
        out = io.StringIO()
        with contextlib.redirect_stdout(out):
            rc = check_workflows.main([repo_dir])
        self.assertEqual(rc, 0, out.getvalue())


if __name__ == "__main__":
    unittest.main()
