"""Unit tests for scripts/bench_trend.py (run by the cheap early CI step:
python3 -m unittest discover -s scripts -p "test_*.py").

The script is fails-soft by contract, so every scenario asserts on the
*output* (warnings emitted or not) and on the return code staying 0.
"""
import contextlib
import io
import json
import os
import tempfile
import unittest

import bench_trend


class BenchTrendCase(unittest.TestCase):
    def setUp(self):
        self._tmp = tempfile.TemporaryDirectory()
        self._old_cwd = os.getcwd()
        os.chdir(self._tmp.name)
        os.mkdir(bench_trend.BASELINE_DIR)

    def tearDown(self):
        os.chdir(self._old_cwd)
        self._tmp.cleanup()

    def write(self, path, payload):
        with open(path, "w") as f:
            json.dump(payload, f)

    def run_main(self, paths):
        out = io.StringIO()
        with contextlib.redirect_stdout(out):
            rc = bench_trend.main(paths)
        return rc, out.getvalue()

    def test_regression_detected(self):
        self.write(os.path.join(bench_trend.BASELINE_DIR, "BENCH_x.json"), {"a_s": 1.0})
        self.write("BENCH_x.json", {"a_s": 1.5})
        rc, out = self.run_main(["BENCH_x.json"])
        self.assertEqual(rc, 0, "the trend check is advisory: rc stays 0")
        self.assertIn("::warning", out)
        self.assertIn("bench regression", out)
        self.assertIn("a_s", out)
        self.assertIn("1 warning(s)", out)

    def test_nested_regression_detected(self):
        self.write(
            os.path.join(bench_trend.BASELINE_DIR, "BENCH_x.json"),
            {"zorder": {"update_s": 2.0}},
        )
        self.write("BENCH_x.json", {"zorder": {"update_s": 4.0}})
        rc, out = self.run_main(["BENCH_x.json"])
        self.assertEqual(rc, 0)
        self.assertIn("::warning", out)
        self.assertIn("zorder.update_s", out)

    def test_missing_baseline_reported(self):
        self.write("BENCH_x.json", {"a_s": 1.0})
        rc, out = self.run_main(["BENCH_x.json"])
        self.assertEqual(rc, 0)
        self.assertIn("no baseline", out)
        self.assertIn("a_s", out, "current values are printed so a baseline can be seeded")
        self.assertNotIn("::warning", out, "a missing baseline is informational, not a warning")

    def test_within_tolerance_silent(self):
        self.write(os.path.join(bench_trend.BASELINE_DIR, "BENCH_x.json"), {"a_s": 1.0})
        self.write("BENCH_x.json", {"a_s": 1.1})  # +10% < the 20% threshold
        rc, out = self.run_main(["BENCH_x.json"])
        self.assertEqual(rc, 0)
        self.assertNotIn("::warning", out)
        self.assertIn("ok BENCH_x.json:a_s", out)
        self.assertIn("0 warning(s)", out)

    def test_improvement_is_silent(self):
        self.write(os.path.join(bench_trend.BASELINE_DIR, "BENCH_x.json"), {"a_s": 1.0})
        self.write("BENCH_x.json", {"a_s": 0.3})
        rc, out = self.run_main(["BENCH_x.json"])
        self.assertEqual(rc, 0)
        self.assertNotIn("::warning", out)

    def test_missing_snapshot_warns_but_does_not_fail(self):
        rc, out = self.run_main(["BENCH_never_written.json"])
        self.assertEqual(rc, 0)
        self.assertIn("::warning", out)
        self.assertIn("missing", out)

    def test_new_keys_without_baseline_are_reported_not_flagged(self):
        self.write(os.path.join(bench_trend.BASELINE_DIR, "BENCH_x.json"), {"a_s": 1.0})
        self.write("BENCH_x.json", {"a_s": 1.0, "persist": {"save_s": 0.5}})
        rc, out = self.run_main(["BENCH_x.json"])
        self.assertEqual(rc, 0)
        self.assertNotIn("::warning", out)
        self.assertIn("persist.save_s", out)
        self.assertIn("without a baseline", out)

    def test_fitsne_snapshot_shape(self):
        # BENCH_fitsne.json nests timings under fitsne/crossover; the
        # kernel_rebuilds counter and estimate_n are not timings and must
        # never trip the trend even when they change.
        base = {
            "fitsne": {"cold_step_s": 0.5, "step_s": 0.1, "kernel_rebuilds": 0},
            "crossover": {
                "n10000": {"bh_step_s": 0.02, "fit_step_s": 0.03},
                "estimate_n": 50000,
            },
        }
        cur = {
            "fitsne": {"cold_step_s": 0.5, "step_s": 0.2, "kernel_rebuilds": 9},
            "crossover": {
                "n10000": {"bh_step_s": 0.02, "fit_step_s": 0.03},
                "estimate_n": 10000,
            },
        }
        self.write(os.path.join(bench_trend.BASELINE_DIR, "BENCH_fitsne.json"), base)
        self.write("BENCH_fitsne.json", cur)
        rc, out = self.run_main(["BENCH_fitsne.json"])
        self.assertEqual(rc, 0)
        self.assertIn("fitsne.step_s", out, "the regressed steady-step timing is flagged")
        self.assertIn("::warning", out)
        self.assertIn("1 warning(s)", out, "counters and estimate_n do not warn")
        self.assertIn("ok BENCH_fitsne.json:crossover.n10000.bh_step_s", out)

    def test_default_snapshot_set_includes_fitsne_knn_and_serving(self):
        self.assertIn("rust/BENCH_fitsne.json", bench_trend.DEFAULT_SNAPSHOTS)
        self.assertIn("rust/BENCH_knn.json", bench_trend.DEFAULT_SNAPSHOTS)
        self.assertIn("rust/BENCH_serving.json", bench_trend.DEFAULT_SNAPSHOTS)
        self.assertEqual(len(bench_trend.DEFAULT_SNAPSHOTS), 5)

    def test_serving_snapshot_shape(self):
        # BENCH_serving.json mixes duration keys (step_p50_s, step_p99_s,
        # cache_miss_s, cache_hit_s — higher is worse) with throughput rates
        # (sessions_per_s — HIGHER is better). Rates share the `_s` suffix
        # but must never be compared as timings: a throughput improvement
        # would otherwise be flagged as a regression.
        base = {
            "serving": {
                "cache_miss_s": 1.0,
                "cache_hit_s": 0.01,
                "n4": {"sessions_per_s": 2.0, "step_p50_s": 0.01, "step_p99_s": 0.05},
            }
        }
        cur = {
            "serving": {
                "cache_miss_s": 1.0,
                "cache_hit_s": 0.01,
                # throughput DOUBLED (an improvement) — must stay silent
                "n4": {"sessions_per_s": 4.0, "step_p50_s": 0.03, "step_p99_s": 0.05},
            }
        }
        self.write(os.path.join(bench_trend.BASELINE_DIR, "BENCH_serving.json"), base)
        self.write("BENCH_serving.json", cur)
        rc, out = self.run_main(["BENCH_serving.json"])
        self.assertEqual(rc, 0)
        self.assertIn("::warning", out)
        self.assertIn("serving.n4.step_p50_s", out, "the regressed p50 step timing is flagged")
        self.assertIn("1 warning(s)", out, "the sessions_per_s rate never trips the trend")
        self.assertNotIn("sessions_per_s", out.split("::warning")[1].splitlines()[0])
        self.assertIn("ok BENCH_serving.json:serving.cache_hit_s", out)

    def test_per_s_rates_are_exempt_from_the_timing_trend(self):
        self.assertTrue(bench_trend.is_timing_key("step_p99_s"))
        self.assertTrue(bench_trend.is_timing_key("serving.cache_hit_s"))
        self.assertFalse(bench_trend.is_timing_key("sessions_per_s"))
        self.assertFalse(bench_trend.is_timing_key("serving.n8.sessions_per_s"))
        self.assertFalse(bench_trend.is_timing_key("speedup"))
        # a halved rate (worse throughput) is also silent: rates are
        # reported by the bench, trended by eye, never auto-flagged
        self.write(
            os.path.join(bench_trend.BASELINE_DIR, "BENCH_x.json"),
            {"sessions_per_s": 4.0, "a_s": 1.0},
        )
        self.write("BENCH_x.json", {"sessions_per_s": 2.0, "a_s": 1.0})
        rc, out = self.run_main(["BENCH_x.json"])
        self.assertEqual(rc, 0)
        self.assertNotIn("::warning", out)

    def test_new_per_s_keys_are_not_listed_as_baselineless_timings(self):
        self.write(os.path.join(bench_trend.BASELINE_DIR, "BENCH_x.json"), {"a_s": 1.0})
        self.write("BENCH_x.json", {"a_s": 1.0, "serving": {"sessions_per_s": 3.0}})
        rc, out = self.run_main(["BENCH_x.json"])
        self.assertEqual(rc, 0)
        self.assertNotIn("without a baseline", out)
        self.assertNotIn("::warning", out)

    def test_knn_snapshot_shape(self):
        # BENCH_knn.json nests timings under knn_recall; recall values and
        # default_ef are quality/config numbers, not timings — they may drift
        # (e.g. a recall improvement) without tripping the trend. Only the
        # *_s search/build timings participate.
        base = {
            "knn_recall": {
                "build_s": 1.0,
                "exact_search_s": 2.0,
                "default_ef": 64,
                "default_recall": 0.95,
                "ef64": {"search_s": 0.1, "recall": 0.95},
            }
        }
        cur = {
            "knn_recall": {
                "build_s": 1.0,
                "exact_search_s": 2.0,
                "default_ef": 64,
                "default_recall": 0.40,  # silent: recall is not a timing
                "ef64": {"search_s": 0.3, "recall": 0.40},  # 3x slower: flagged
            }
        }
        self.write(os.path.join(bench_trend.BASELINE_DIR, "BENCH_knn.json"), base)
        self.write("BENCH_knn.json", cur)
        rc, out = self.run_main(["BENCH_knn.json"])
        self.assertEqual(rc, 0)
        self.assertIn("::warning", out)
        self.assertIn("knn_recall.ef64.search_s", out, "the regressed search timing is flagged")
        self.assertIn("1 warning(s)", out, "recall drift and default_ef never warn")
        self.assertIn("ok BENCH_knn.json:knn_recall.build_s", out)

    def test_non_timing_keys_are_ignored(self):
        # only *_s keys participate in the trend; counters may drift freely
        self.write(
            os.path.join(bench_trend.BASELINE_DIR, "BENCH_x.json"),
            {"a_s": 1.0, "n": 1000, "speedup": 2.0},
        )
        self.write("BENCH_x.json", {"a_s": 1.0, "n": 9000, "speedup": 0.1})
        rc, out = self.run_main(["BENCH_x.json"])
        self.assertEqual(rc, 0)
        self.assertNotIn("::warning", out)


if __name__ == "__main__":
    unittest.main()
