#!/usr/bin/env python3
"""Lint the GitHub Actions workflows in .github/workflows/.

The workflows are load-bearing code (the serving smoke, the determinism
matrix, the baseline-promotion bootstrap all live there) but nothing parsed
them until a cross-workflow reference broke silently: `workflow_run`
triggers name their upstream workflow by its display `name:`, and a rename
on one side orphans the other without any error anywhere. This linter makes
those contracts explicit:

  1. every *.yml / *.yaml file parses as YAML;
  2. every workflow has a `name:`, a trigger block, and `jobs:`;
  3. every job has `runs-on:` and either `steps:` or a reusable-workflow
     `uses:`;
  4. every `workflow_run.workflows` entry matches the `name:` of a workflow
     that actually exists in the same directory;
  5. the main CI workflow (ci.yml) still defines its required job skeleton —
     branch protection and the baseline-promotion trigger assume those job
     ids exist, and deleting one silently weakens the gate.

A YAML 1.1 gotcha this must survive: `on:` is parsed by safe_load as the
BOOLEAN True (the same rule that turns `branches: [yes]` into booleans), so
the trigger block is found under the key True, not the string "on".

Unlike bench_trend.py this is a HARD gate: exit 1 on any finding. It checks
structure only — stale structure is exactly the class of bug it exists for —
and runs on the system python (PyYAML ships on the CI runners).

Usage: check_workflows.py [workflows_dir]   (default .github/workflows)
"""
import os
import sys

try:
    import yaml
except ImportError:  # pragma: no cover - CI runners ship PyYAML
    print("::error::check_workflows.py needs PyYAML (python3-yaml)")
    sys.exit(1)

DEFAULT_DIR = os.path.join(".github", "workflows")

# safe_load applies YAML 1.1 boolean rules to KEYS too: `on:` loads as the
# key True. Accept both spellings so the linter never misreports a workflow
# as trigger-less just because of the YAML spec.
ON_KEYS = ("on", True)

# Required job skeletons, keyed by workflow file basename. These are the job
# ids that outside contracts depend on existing (branch-protection checks,
# the promote-baselines workflow_run trigger, the tiering described in
# ROADMAP.md / docs/static-analysis.md). Removing or renaming one is a
# deliberate act: update this table in the same commit, with the rationale.
REQUIRED_JOBS = {
    "ci.yml": {
        "bench-trend-unit-tests",
        "fmt",
        "lint",
        "build-and-test",
        "serve-smoke",
        "determinism",
        "miri",
        "tsan",
    },
}


def trigger_block(doc):
    for key in ON_KEYS:
        if key in doc:
            return doc[key]
    return None


def check_workflow(path, doc, errors):
    """Structural checks for one parsed workflow document."""
    if not isinstance(doc, dict):
        errors.append(f"{path}: top level is {type(doc).__name__}, expected a mapping")
        return
    if not isinstance(doc.get("name"), str) or not doc.get("name").strip():
        errors.append(f"{path}: missing workflow `name:` (workflow_run refers to it)")
    if trigger_block(doc) is None:
        errors.append(f"{path}: missing trigger block (`on:`)")
    jobs = doc.get("jobs")
    if not isinstance(jobs, dict) or not jobs:
        errors.append(f"{path}: missing or empty `jobs:`")
        return
    for job_id, job in jobs.items():
        if not isinstance(job, dict):
            errors.append(f"{path}: job `{job_id}` is not a mapping")
            continue
        if "uses" in job:
            continue  # reusable workflow call: no runs-on/steps of its own
        if "runs-on" not in job:
            errors.append(f"{path}: job `{job_id}` has no `runs-on:`")
        steps = job.get("steps")
        if not isinstance(steps, list) or not steps:
            errors.append(f"{path}: job `{job_id}` has no `steps:`")


def check_required_jobs(path, doc, errors):
    """If this file has a pinned skeleton, every required job id must exist."""
    required = REQUIRED_JOBS.get(os.path.basename(path))
    if not required or not isinstance(doc, dict):
        return
    jobs = doc.get("jobs")
    have = set(jobs) if isinstance(jobs, dict) else set()
    for job_id in sorted(required - have):
        errors.append(
            f"{path}: required job `{job_id}` is missing — the "
            f"{os.path.basename(path)} skeleton is pinned in REQUIRED_JOBS "
            f"(check_workflows.py); change both together or not at all"
        )


def workflow_run_references(doc):
    """Names listed under the workflow_run trigger, if any."""
    trig = trigger_block(doc)
    if not isinstance(trig, dict):
        return []
    wr = trig.get("workflow_run")
    if not isinstance(wr, dict):
        return []
    names = wr.get("workflows")
    if isinstance(names, str):
        return [names]
    if isinstance(names, list):
        return [n for n in names if isinstance(n, str)]
    return []


def main(argv):
    wdir = argv[0] if argv else DEFAULT_DIR
    if not os.path.isdir(wdir):
        print(f"::error::workflow directory {wdir} does not exist")
        return 1
    files = sorted(
        f for f in os.listdir(wdir) if f.endswith((".yml", ".yaml"))
    )
    if not files:
        print(f"::error::no workflow files found in {wdir}")
        return 1

    errors = []
    docs = {}
    for fname in files:
        path = os.path.join(wdir, fname)
        try:
            with open(path) as f:
                docs[path] = yaml.safe_load(f)
        except yaml.YAMLError as e:
            errors.append(f"{path}: YAML parse error: {e}")
    for path, doc in docs.items():
        check_workflow(path, doc, errors)
        check_required_jobs(path, doc, errors)

    # Cross-workflow references: workflow_run.workflows entries must name a
    # workflow that exists here, by its display name.
    known_names = {
        doc.get("name")
        for doc in docs.values()
        if isinstance(doc, dict) and isinstance(doc.get("name"), str)
    }
    for path, doc in docs.items():
        if not isinstance(doc, dict):
            continue
        for ref in workflow_run_references(doc):
            if ref not in known_names:
                errors.append(
                    f"{path}: workflow_run references `{ref}`, but no workflow in "
                    f"{wdir} has that `name:` (known: {sorted(known_names)})"
                )

    for e in errors:
        print(f"::error::{e}")
    print(f"workflow lint: {len(files)} file(s), {len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
