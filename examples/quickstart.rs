//! Quickstart: embed a small Gaussian-mixture dataset through the session
//! API — fit the affinities once, run a convergence-controlled descent with
//! streaming snapshots, then reuse the same affinities for a second seed —
//! and write the scatter plot.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```

use acc_tsne::data::synthetic::gaussian_mixture;
use acc_tsne::parallel::ThreadPool;
use acc_tsne::tsne::{
    Affinities, Convergence, KnnGraph, ObserverControl, StagePlan, TsneConfig, TsneSession,
};
use acc_tsne::viz;

fn main() {
    // 2000 points in 16-D, 10 well-separated clusters.
    let ds = gaussian_mixture::<f64>(2_000, 16, 10, 6.0, 42);
    println!("dataset: n={} d={} classes=10", ds.n, ds.d);

    let cfg = TsneConfig {
        perplexity: 30.0,
        n_iter: 500,
        ..TsneConfig::default()
    };

    // Phase 1 — the affinity fit (KNN → BSP → symmetrize), computed ONCE.
    let plan = StagePlan::acc_tsne();
    let pool = ThreadPool::with_all_cores();
    let aff = Affinities::fit(&pool, &ds.points, ds.n, ds.d, cfg.perplexity, &plan)
        .expect("hostile shapes come back as typed FitErrors");
    println!(
        "affinities: nnz={} fit in {:.2}s",
        aff.p().nnz(),
        aff.step_times().total()
    );

    // Phase 2 — a session with convergence control and streaming snapshots.
    let mut session = TsneSession::new(&aff, plan, cfg).expect("preset plans validate");
    session.set_observer(100, |snap| {
        println!(
            "  iter {:>4}: KL = {:.4}  |grad| = {:.3e}",
            snap.iter, snap.kl, snap.grad_norm
        );
        ObserverControl::Continue
    });
    // Convergence is checked after the early-exaggeration phase (250 iters
    // by default) and the first check always registers progress, so the
    // no-progress window must fit strictly inside the remaining budget:
    // 250 + 100 < 500.
    let outcome = session.run_until(Convergence {
        max_iter: cfg.n_iter,
        min_grad_norm: 1e-7,
        n_iter_without_progress: 100,
    });
    let result = session.finish();

    println!("KL divergence: {:.4}", result.kl_divergence);
    println!("iterations   : {} ({:?})", outcome.n_iter, outcome.reason);
    println!("gradient time: {:.2}s", result.step_times.total());
    for (step, pct) in result.step_times.percentages() {
        println!(
            "  {:<11} {:>8.3}s  {:>5.1}%",
            step.name(),
            result.step_times.get(step),
            pct
        );
    }

    // The fit is reusable: a second descent from another seed costs zero
    // KNN/BSP time.
    let mut cfg_b = cfg;
    cfg_b.seed = 1234;
    let mut session_b = TsneSession::new(&aff, plan, cfg_b).expect("preset plans validate");
    session_b.run(cfg_b.n_iter);
    let result_b = session_b.finish();
    println!(
        "second seed  : KL = {:.4} (same affinities, no KNN/BSP recompute)",
        result_b.kl_divergence
    );

    // Persistence — the fit outlives the process, and a session survives a
    // restart. The affinities artifact is a versioned, checksummed binary;
    // the checkpoint stores un-permuted optimizer state, and resuming is
    // bit-identical to never having stopped (fixed thread count).
    std::fs::create_dir_all("results").ok();
    aff.save("results/quickstart.affinities").expect("save affinities");
    let aff_loaded =
        Affinities::<f64>::load("results/quickstart.affinities").expect("load affinities");
    println!(
        "persisted fit: results/quickstart.affinities (nnz={}, reload bit-exact: {})",
        aff_loaded.p().nnz(),
        aff_loaded.p().val == aff.p().val
    );

    // KNN-graph persistence — the multi-perplexity serving path. KNN
    // dominates the fit, but the graph depends only on the data and k: save
    // it once (built at the LARGEST sweep perplexity's ⌊3u⌋), reload it
    // anywhere, and every re-fit is BSP-only. A re-fit at the fit perplexity
    // is bit-identical to the full fit above.
    let graph = KnnGraph::build_for_perplexity(&pool, &ds.points, ds.n, ds.d, 30.0, &plan)
        .expect("valid shape");
    graph.save("results/quickstart.knn").expect("save knn graph");
    let graph = KnnGraph::<f64>::load("results/quickstart.knn").expect("load knn graph");
    graph.verify_source(&ds.points, ds.n, ds.d).expect("same dataset");
    println!(
        "persisted knn: results/quickstart.knn (k={}, engine={})",
        graph.k(),
        graph.engine()
    );
    for u in [10.0, 20.0, 30.0] {
        let aff_u = Affinities::from_knn(&pool, &graph, u, &plan).expect("floor(3u) <= k");
        let mut sess_u = TsneSession::new(&aff_u, plan, cfg).expect("preset plans validate");
        sess_u.run(250);
        let bsp_s = aff_u.step_times().total();
        println!(
            "  perplexity {u:>4}: KL = {:.4} (re-fit in {bsp_s:.3}s, no KNN{})",
            sess_u.finish().kl_divergence,
            if u == 30.0 && aff_u.p().val == aff.p().val {
                "; P bit-identical to the full fit"
            } else {
                ""
            }
        );
    }

    let mut cfg_c = cfg;
    cfg_c.seed = 7;
    let mut session_c = TsneSession::new(&aff_loaded, plan, cfg_c).expect("preset plans validate");
    session_c.run(100);
    session_c.checkpoint("results/quickstart.ckpt").expect("write checkpoint");
    drop(session_c); // simulate a restart: only the file carries the state
    let mut resumed = TsneSession::restore(&aff_loaded, plan, cfg_c, "results/quickstart.ckpt")
        .expect("restore checkpoint");
    resumed.run(100);
    println!(
        "checkpoint/resume: KL = {:.4} after {} iterations (100 before + 100 after restart)",
        resumed.finish().kl_divergence,
        200
    );

    viz::write_svg("results/quickstart.svg", &result.embedding, &ds.labels, 768)
        .expect("write plot");
    println!("plot: results/quickstart.svg");
}
