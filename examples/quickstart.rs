//! Quickstart: embed a small Gaussian-mixture dataset with Acc-t-SNE and
//! write the scatter plot.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```

use acc_tsne::data::synthetic::gaussian_mixture;
use acc_tsne::tsne::{run_tsne, Implementation, TsneConfig};
use acc_tsne::viz;

fn main() {
    // 2000 points in 16-D, 10 well-separated clusters.
    let ds = gaussian_mixture::<f64>(2_000, 16, 10, 6.0, 42);
    println!("dataset: n={} d={} classes=10", ds.n, ds.d);

    let cfg = TsneConfig {
        perplexity: 30.0,
        n_iter: 500,
        ..TsneConfig::default()
    };
    let result = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);

    println!("KL divergence: {:.4}", result.kl_divergence);
    println!("total time   : {:.2}s", result.step_times.total());
    for (step, pct) in result.step_times.percentages() {
        println!(
            "  {:<11} {:>8.3}s  {:>5.1}%",
            step.name(),
            result.step_times.get(step),
            pct
        );
    }

    std::fs::create_dir_all("results").ok();
    viz::write_svg("results/quickstart.svg", &result.embedding, &ds.labels, 768)
        .expect("write plot");
    println!("plot: results/quickstart.svg");
}
