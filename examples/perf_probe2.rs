//! Crossover probe: sequential vs parallel morton build across n.
use acc_tsne::common::rng::Rng;
use acc_tsne::parallel::ThreadPool;
use std::time::Instant;
fn main() {
    let mut rng = Rng::new(1);
    let pool = ThreadPool::with_all_cores();
    let pool1 = ThreadPool::new(1);
    for n in [10_000usize, 25_000, 50_000, 100_000, 200_000, 400_000] {
        let pos: Vec<f64> = (0..2*n).map(|_| rng.next_gaussian()).collect();
        let iters = (2_000_000 / n).max(3);
        for (name, p) in [("seq", &pool1), ("par", &pool)] {
            let t = Instant::now();
            let mut c = 0;
            for _ in 0..iters { c += acc_tsne::quadtree::builder_morton::build_morton(p, &pos).nodes.len(); }
            println!("n={n} {name}: {:.2}ms ({c})", t.elapsed().as_secs_f64()*1000.0/iters as f64);
        }
    }
}
