//! Three-layer composition demo: the L1 Pallas kernels (AOT-lowered through
//! the L2 JAX graphs into `artifacts/*.hlo.txt`) executing on the L3 hot path
//! via PJRT, side by side with the native Rust engines.
//!
//! Requires `make artifacts`.
//!
//! ```sh
//! cargo run --release --offline --example xla_offload
//! ```

use acc_tsne::common::timer::Timer;
use acc_tsne::data::synthetic::gaussian_mixture;
use acc_tsne::knn::{BruteForceKnn, KnnEngine};
use acc_tsne::parallel::ThreadPool;
use acc_tsne::runtime::engines::{XlaAttractive, XlaKnn, XlaRepulsiveDense};
use acc_tsne::runtime::Runtime;
use acc_tsne::tsne::{run_tsne_custom, Implementation, Layout, TsneConfig};

fn main() {
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("{e:#}");
            std::process::exit(1);
        }
    };
    println!(
        "PJRT platform: {} ({} devices)",
        rt.client.platform_name(),
        rt.client.device_count()
    );

    let ds = gaussian_mixture::<f64>(1_000, 20, 8, 6.0, 42);
    let pool = ThreadPool::with_all_cores();

    // --- KNN: native blocked vs AOT Pallas sqdist tiles.
    println!("\n[knn] n={} d={} k=30", ds.n, ds.d);
    let t = Timer::start();
    let native = BruteForceKnn::default().search(&pool, &ds.points, ds.n, ds.d, 30);
    let t_native = t.elapsed();
    let xla_knn = XlaKnn::new(&rt).expect("compile knn_sqdist artifact");
    let t = Timer::start();
    let offl: acc_tsne::knn::NeighborLists<f64> = xla_knn.search(&pool, &ds.points, ds.n, ds.d, 30);
    let t_xla = t.elapsed();
    let agree = (0..ds.n)
        .filter(|&i| native.neighbors(i)[0] == offl.neighbors(i)[0])
        .count();
    println!("  native {t_native:.3}s | xla {t_xla:.3}s | nearest-neighbor agreement {agree}/{}", ds.n);

    // --- Dense repulsion: AOT Pallas tile vs exact oracle.
    let y32: Vec<f32> = (0..2 * 600).map(|i| ((i * 37) % 100) as f32 / 10.0 - 5.0).collect();
    let rep = XlaRepulsiveDense::new(&rt).expect("compile repulsive_dense artifact");
    let (raw, z) = rep.exact(&y32).expect("execute");
    let y64: Vec<f64> = y32.iter().map(|&v| v as f64).collect();
    let (want, want_z) = acc_tsne::gradient::exact::exact_repulsive(&pool, &y64);
    let max_err = raw
        .iter()
        .zip(want.iter())
        .map(|(g, w)| ((*g as f64) - w).abs())
        .fold(0.0f64, f64::max);
    println!("\n[repulsive_dense] Z xla {z:.1} vs exact {want_z:.1}; max force err {max_err:.2e}");

    // --- Full t-SNE with the XLA attractive engine on the hot path.
    println!("\n[end-to-end] acc-t-sne with XLA attractive engine (300 pts, 100 iters)");
    let small = gaussian_mixture::<f64>(300, 8, 4, 8.0, 7);
    let cfg = TsneConfig {
        perplexity: 10.0,
        n_iter: 100,
        // The AOT artifact bakes the original sparsity pattern; keep the
        // gradient state in original order rather than the Z-order default.
        layout: Some(Layout::Original),
        ..TsneConfig::default()
    };
    let eng = XlaAttractive::new(&rt).expect("compile attractive artifact");
    let t = Timer::start();
    let r_xla = run_tsne_custom(&small.points, small.n, small.d, &cfg, Implementation::AccTsne, Some(&eng));
    let t_xla = t.elapsed();
    let t = Timer::start();
    let r_nat = run_tsne_custom(&small.points, small.n, small.d, &cfg, Implementation::AccTsne, None);
    let t_nat = t.elapsed();
    println!(
        "  KL xla-engine {:.4} ({t_xla:.2}s) vs native {:.4} ({t_nat:.2}s)",
        r_xla.kl_divergence, r_nat.kl_divergence
    );
    println!("\nall three layers compose: python authored, rust executed, no python at runtime");
}
