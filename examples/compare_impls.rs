//! All five implementations on one dataset — a one-dataset slice of the
//! paper's Figure 4.
//!
//! ```sh
//! cargo run --release --offline --example compare_impls [dataset] [scale] [iters]
//! ```

use acc_tsne::data::datasets::PaperDataset;
use acc_tsne::parallel::ThreadPool;
use acc_tsne::tsne::{run_tsne, Implementation, TsneConfig};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let name = args.get(1).map(String::as_str).unwrap_or("fashion-mnist");
    let scale: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0.02);
    let iters: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(200);
    let kind = PaperDataset::from_name(name).expect("unknown dataset (see `acc-tsne info`)");

    let pool = ThreadPool::with_all_cores();
    let ds = kind.generate::<f64>(scale, 42, &pool);
    println!("{name}: n={} d={} ({} iters, {} threads)\n", ds.n, ds.d, iters, pool.n_threads());

    let cfg = TsneConfig {
        n_iter: iters,
        ..TsneConfig::default()
    };
    println!("{:<12} {:>10} {:>10} {:>8}", "impl", "time (s)", "KL", "speedup");
    let mut base = None;
    for imp in Implementation::ALL {
        let r = run_tsne(&ds.points, ds.n, ds.d, &cfg, imp);
        let t = r.step_times.total();
        if base.is_none() {
            base = Some(t);
        }
        println!(
            "{:<12} {t:>10.2} {:>10.4} {:>7.1}x",
            imp.name(),
            r.kl_divergence,
            base.unwrap() / t
        );
    }
}
