//! MNIST-analog per-step comparison: daal4py-like vs Acc-t-SNE — a miniature
//! of the paper's Tables 5/6 on the 70000×784-shaped dataset.
//!
//! ```sh
//! cargo run --release --offline --example mnist_like [scale] [iters]
//! ```

use acc_tsne::common::timer::Step;
use acc_tsne::data::datasets::PaperDataset;
use acc_tsne::parallel::ThreadPool;
use acc_tsne::tsne::{run_tsne, Implementation, TsneConfig};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let scale: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.02);
    let iters: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(200);

    let pool = ThreadPool::with_all_cores();
    let ds = PaperDataset::Mnist.generate::<f64>(scale, 42, &pool);
    println!("mnist-analog: n={} d={} ({} iters)", ds.n, ds.d, iters);

    let cfg = TsneConfig {
        n_iter: iters,
        ..TsneConfig::default()
    };
    let daal = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::Daal4pyLike);
    let acc = run_tsne(&ds.points, ds.n, ds.d, &cfg, Implementation::AccTsne);

    println!("\n{:<12} {:>12} {:>12} {:>9}", "step", "daal4py (s)", "acc (s)", "speedup");
    for step in [
        Step::Knn,
        Step::Bsp,
        Step::TreeBuild,
        Step::Summarize,
        Step::Attractive,
        Step::Repulsive,
    ] {
        let (a, b) = (daal.step_times.get(step), acc.step_times.get(step));
        println!("{:<12} {a:>12.3} {b:>12.3} {:>8.1}x", step.name(), a / b.max(1e-12));
    }
    let (ta, tb) = (daal.step_times.total(), acc.step_times.total());
    println!("{:<12} {ta:>12.3} {tb:>12.3} {:>8.1}x", "TOTAL", ta / tb);
    println!(
        "\nKL: daal4py-like {:.4} vs acc-t-sne {:.4} (same accuracy expected)",
        daal.kl_divergence, acc.kl_divergence
    );
}
