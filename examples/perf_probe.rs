use acc_tsne::knn::{BruteForceKnn, KnnEngine};
use acc_tsne::common::rng::Rng;
use acc_tsne::parallel::ThreadPool;
use std::time::Instant;
fn main() {
    let mut rng = Rng::new(1);
    for (n, d) in [(20000usize, 20usize), (7000, 784)] {
        let data: Vec<f64> = (0..n*d).map(|_| rng.next_gaussian()).collect();
        let pool = ThreadPool::with_all_cores();
        let t = Instant::now();
        let r = BruteForceKnn::default().search(&pool, &data, n, d, 90);
        println!("knn n={n} d={d}: {:.3}s (checksum {})", t.elapsed().as_secs_f64(), r.indices[0]);
    }
    // tree build at small and large n
    for n in [2000usize, 200000] {
        let pos: Vec<f64> = (0..2*n).map(|_| rng.next_gaussian()).collect();
        let pool = ThreadPool::with_all_cores();
        let t = Instant::now();
        let mut cnt = 0;
        let iters = if n < 10000 { 200 } else { 20 };
        for _ in 0..iters { cnt += acc_tsne::quadtree::builder_morton::build_morton(&pool, &pos).nodes.len(); }
        println!("tree n={n}: {:.3}ms/build ({cnt})", t.elapsed().as_secs_f64()*1000.0/iters as f64);
    }
}
