//! End-to-end single-cell RNA-seq pipeline — the workload the paper's
//! motivating application (mouse brain 1.3M cells) runs: synthetic scRNA
//! counts → PCA to 20 principal components (as the paper's preprocessing) →
//! full BH t-SNE with per-phase logging of the KL loss curve.
//!
//! This is the repo's end-to-end validation driver: it exercises every
//! library layer (data gen, PCA substrate, KNN, BSP, symmetrization, morton
//! quadtree, summarization, SIMD attractive, BH repulsive, optimizer) through
//! the *public step-level API* rather than the one-shot `run_tsne`, and logs
//! the KL curve. Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --offline --example scrna_pipeline [n_cells] [iters]
//! ```

use acc_tsne::common::timer::Timer;
use acc_tsne::data::pca::pca;
use acc_tsne::data::synthetic::scrna_like;
use acc_tsne::gradient::attractive::{attractive_forces, Variant};
use acc_tsne::gradient::exact::kl_with_z;
use acc_tsne::gradient::repulsive::repulsive_forces_scalar_into;
use acc_tsne::gradient::update::{random_init, Optimizer, UpdateParams};
use acc_tsne::knn::{BruteForceKnn, KnnEngine};
use acc_tsne::metrics::neighbor_preservation;
use acc_tsne::parallel::ThreadPool;
use acc_tsne::perplexity::{binary_search_perplexity, ParMode};
use acc_tsne::quadtree::builder_morton::build_morton;
use acc_tsne::quadtree::summarize::summarize_parallel;
use acc_tsne::sparse::symmetrize;
use acc_tsne::viz;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n_cells: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(20_000);
    let n_iter: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(500);
    let pool = ThreadPool::with_all_cores();
    let total = Timer::start();

    // --- Phase 1: synthetic scRNA counts (30 cell types, zipf sizes, dropout).
    println!("[1/5] generating scRNA-like counts: {n_cells} cells × 200 genes");
    let raw = scrna_like::<f64>(n_cells, 200, 30, 0.6, 7);

    // --- Phase 2: PCA → 20 PCs (the paper's preprocessing).
    println!("[2/5] PCA → 20 components");
    let t = Timer::start();
    let (pcs, eig) = pca(&pool, &raw.points, raw.n, 200, 20, 30, 11);
    println!(
        "      {:.2}s; top-5 explained variance: {:?}",
        t.elapsed(),
        &eig[..5].iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>()
    );

    // --- Phase 3: KNN + perplexity calibration + symmetrization.
    let perplexity = 30.0;
    let k = (3.0 * perplexity) as usize;
    println!("[3/5] KNN (k={k}) + BSP + symmetrize");
    let t = Timer::start();
    let knn = BruteForceKnn::default().search(&pool, &pcs, raw.n, 20, k);
    let cond = binary_search_perplexity(&pool, &knn, perplexity, ParMode::Parallel);
    let p = symmetrize(&pool, &knn, &cond.p);
    println!("      {:.2}s; P nnz = {}", t.elapsed(), p.nnz());

    // --- Phase 4: gradient descent with the Acc-t-SNE step set, logging KL.
    println!("[4/5] gradient descent ({n_iter} iters), KL curve:");
    let mut y = random_init::<f64>(raw.n, 42);
    let mut opt = Optimizer::new(raw.n, UpdateParams::default());
    let mut attr = vec![0.0f64; 2 * raw.n];
    let mut rep_raw = vec![0.0f64; 2 * raw.n];
    let theta = 0.5;
    let t = Timer::start();
    for iter in 0..n_iter {
        let mut tree = build_morton(&pool, &y);
        summarize_parallel(&pool, &mut tree);
        // allocation-free repulsive pass + the fused combine+update sweep
        // (one pass over 2n instead of separate combine and step passes)
        let z = repulsive_forces_scalar_into(&pool, &tree, theta, &mut rep_raw);
        attractive_forces(&pool, &p, &y, Variant::Simd, &mut attr);
        // the fused sweep returns the squared gradient norm for free — the
        // same signal TsneSession::run_until uses for convergence stopping
        let grad_norm_sq = opt.fused_combine_step(&pool, iter, &attr, &rep_raw, z, &mut y);
        if iter % (n_iter / 10).max(1) == 0 || iter + 1 == n_iter {
            let kl = kl_with_z(&p, &y, z);
            println!("      iter {iter:>5}  KL = {kl:.4}  |grad| = {:.3e}", grad_norm_sq.sqrt());
        }
    }
    println!("      gradient phase: {:.2}s", t.elapsed());

    // --- Phase 5: quality + outputs.
    println!("[5/5] quality metrics + plots");
    let np = neighbor_preservation(&pool, &pcs, raw.n, 20, &y, 15);
    println!("      15-NN preservation: {:.3}", np);
    std::fs::create_dir_all("results").ok();
    viz::write_svg("results/scrna_embedding.svg", &y, &raw.labels, 900).expect("plot");
    acc_tsne::data::io::write_embedding_csv("results/scrna_embedding.csv", &y, &raw.labels)
        .expect("csv");
    println!("      results/scrna_embedding.{{svg,csv}}");
    println!("done in {:.1}s total", total.elapsed());
}
